//! Typed run configuration assembled from a config file and/or CLI flags
//! (flags win).

use super::parse::ConfigFile;
use crate::backend::BackendKind;
use crate::corpus::Scale;
use crate::nmf::{NmfOptions, ObjectiveKind, SequentialOptions, SparsityMode};
use crate::sparse::TieMode;
use anyhow::{bail, Result};

/// Which algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// Algorithm 1 / 2 (+ per-column variant) via SparsityMode
    Als,
    /// Algorithm 3
    Sequential,
}

#[derive(Clone, Debug)]
pub struct RunConfig {
    pub corpus: String,
    /// factorize against an on-disk `.estdm` corpus store instead of a
    /// resident corpus (`--corpus-store` / `[corpus] store`); streams
    /// `A` shard-by-shard, bit-identical to in-memory
    pub corpus_store: Option<String>,
    pub scale: Scale,
    pub seed: u64,
    pub algorithm: Algorithm,
    pub backend: BackendKind,
    pub k: usize,
    pub iters: usize,
    pub tol: f64,
    /// which per-half-step math the factorization runs
    /// (`--objective` / `[nmf] objective`): `frobenius` (the paper's
    /// least-squares ALS) or `kl` (multiplicative KL-divergence updates)
    pub objective: String,
    pub sparsity_mode: String,
    pub t_u: Option<usize>,
    pub t_v: Option<usize>,
    /// threshold-mode cutoffs (ablation)
    pub tau_u: Option<f32>,
    pub tau_v: Option<f32>,
    pub init_nnz: Option<usize>,
    pub track_error: bool,
    /// row-parallelism for the ALS hot path; 0 = auto (all cores)
    pub threads: usize,
    /// rows per streamed ALS half-step block; 0 = auto (fixed scratch
    /// budget / k, or the ESNMF_BLOCK_ROWS env override). Bounds peak
    /// intermediate memory at block_rows · k without changing results.
    pub block_rows: usize,
    /// sequential-only: topics per block and iterations per block
    pub block_topics: usize,
    pub iters_per_block: usize,
    /// topic-server connection workers (`esnmf serve`); 0 = auto (all cores)
    pub serve_threads: usize,
    /// topic-server LRU entries for CLASSIFY/FOLDIN responses; 0 disables
    pub serve_cache: usize,
    /// nonzero budget for folded-in document rows; None falls back to
    /// `t_v` (the training-time V budget), and if that is unset too,
    /// fold-in rows are unenforced
    pub foldin_t: Option<usize>,
    /// write a `.esnmf` model snapshot here after factorization
    /// (`--save-model`)
    pub save_model: Option<String>,
    /// serve a persisted snapshot instead of factorizing
    /// (`esnmf serve --model`)
    pub model: Option<String>,
    /// loopback-only admin/observability listener port
    /// (`--admin-port` / `[serve] admin_port`); None = no admin listener
    pub admin_port: Option<u16>,
    /// poll the `--model` file's mtime and hot-swap on change
    /// (`--watch-model` / `[serve] watch_model`)
    pub watch_model: bool,
    /// checkpoint the ALS run every N completed iterations
    /// (`--checkpoint-every`, 0 = off; requires a checkpoint destination —
    /// `--save-model`)
    pub checkpoint_every: usize,
    /// resume a checkpointed run from this snapshot (`--resume`); refuses
    /// on corpus-digest or k mismatch
    pub resume: Option<String>,
    /// seed `U₀` from this snapshot's factors, aligned by term string
    /// (`--warm-start`); the corpus may differ — that is the point
    pub warm_start: Option<String>,
    /// run the factorization as a distributed coordinator
    /// (`--distributed` / `[distributed] enabled`): listen for workers
    /// over the shared `.estdm` and scatter half-step spans to them.
    /// Bit-identical to the single-process run at any worker count.
    pub distributed: bool,
    /// workers to wait for before starting (`--dist-workers`); the run
    /// proceeds short-handed if fewer join within the timeout
    pub dist_workers: usize,
    /// coordinator listen address for worker connections (`--dist-listen`)
    pub dist_listen: String,
    /// seconds to wait for workers to join, and the per-roundtrip read
    /// deadline after which a worker counts as dead (`--dist-timeout`)
    pub dist_timeout_s: u64,
    /// enable the in-memory trace ring for this run without a file sink
    /// (`[trace] enabled`); implied by `trace_path`
    pub trace_enabled: bool,
    /// stream structured trace events (versioned JSONL) to this file
    /// during factorization (`--trace` / `[trace] path`)
    pub trace_path: Option<String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        // single source of truth for the server knobs
        let serve_defaults = crate::coordinator::ServeOptions::default();
        RunConfig {
            corpus: "reuters".into(),
            corpus_store: None,
            scale: Scale::Small,
            seed: 0x5eed,
            algorithm: Algorithm::Als,
            backend: BackendKind::Native,
            k: 5,
            iters: 75,
            tol: 0.0,
            objective: "frobenius".into(),
            sparsity_mode: "none".into(),
            t_u: None,
            t_v: None,
            tau_u: None,
            tau_v: None,
            init_nnz: None,
            track_error: true,
            threads: 0,
            block_rows: 0,
            block_topics: 1,
            iters_per_block: 20,
            serve_threads: serve_defaults.threads,
            serve_cache: serve_defaults.cache_size,
            foldin_t: None,
            save_model: None,
            model: None,
            admin_port: None,
            watch_model: false,
            checkpoint_every: 0,
            resume: None,
            warm_start: None,
            distributed: false,
            dist_workers: 2,
            dist_listen: "127.0.0.1:7611".into(),
            dist_timeout_s: 30,
            trace_enabled: false,
            trace_path: None,
        }
    }
}

impl RunConfig {
    /// Overlay values from a parsed config file.
    pub fn apply_file(&mut self, f: &ConfigFile) -> Result<()> {
        if let Some(v) = f.str("corpus") {
            self.corpus = v.to_string();
        }
        if let Some(v) = f.str("corpus.store") {
            self.corpus_store = Some(v.to_string());
        }
        if let Some(v) = f.str("scale") {
            self.scale = Scale::parse(v)
                .ok_or_else(|| anyhow::anyhow!("bad scale {v:?} in config"))?;
        }
        if let Some(v) = f.u64("seed") {
            self.seed = v;
        }
        if let Some(v) = f.str("algorithm") {
            self.algorithm = match v {
                "als" => Algorithm::Als,
                "sequential" | "seq" => Algorithm::Sequential,
                other => bail!("bad algorithm {other:?}"),
            };
        }
        if let Some(v) = f.str("backend") {
            self.backend = BackendKind::parse(v)
                .ok_or_else(|| anyhow::anyhow!("bad backend {v:?}"))?;
        }
        if let Some(v) = f.usize("nmf.k") {
            self.k = v;
        }
        if let Some(v) = f.usize("nmf.iters") {
            self.iters = v;
        }
        if let Some(v) = f.f64("nmf.tol") {
            self.tol = v;
        }
        if let Some(v) = f.str("nmf.objective") {
            self.objective = v.to_string();
        }
        if let Some(v) = f.bool("nmf.track_error") {
            self.track_error = v;
        }
        if let Some(v) = f.usize("nmf.init_nnz") {
            self.init_nnz = Some(v);
        }
        if let Some(v) = f.threads("nmf.threads") {
            self.threads = v;
        }
        if let Some(v) = f.auto_usize("nmf.block_rows") {
            self.block_rows = v;
        }
        if let Some(v) = f.str("sparsity.mode") {
            self.sparsity_mode = v.to_string();
        }
        if let Some(v) = f.usize("sparsity.t_u") {
            self.t_u = Some(v);
        }
        if let Some(v) = f.usize("sparsity.t_v") {
            self.t_v = Some(v);
        }
        if let Some(v) = f.f64("sparsity.tau_u") {
            self.tau_u = Some(v as f32);
        }
        if let Some(v) = f.f64("sparsity.tau_v") {
            self.tau_v = Some(v as f32);
        }
        if let Some(v) = f.usize("sequential.block_topics") {
            self.block_topics = v;
        }
        if let Some(v) = f.usize("sequential.iters_per_block") {
            self.iters_per_block = v;
        }
        if let Some(v) = f.threads("serve.threads") {
            self.serve_threads = v;
        }
        if let Some(v) = f.usize("serve.cache_size") {
            self.serve_cache = v;
        }
        if let Some(v) = f.usize("serve.foldin_t") {
            self.foldin_t = Some(v);
        }
        if let Some(v) = f.str("serve.model") {
            self.model = Some(v.to_string());
        }
        if let Some(v) = f.usize("serve.admin_port") {
            anyhow::ensure!(
                v > 0 && v <= u16::MAX as usize,
                "bad serve.admin_port {v} in config (1..=65535)"
            );
            self.admin_port = Some(v as u16);
        }
        if let Some(v) = f.bool("serve.watch_model") {
            self.watch_model = v;
        }
        if let Some(v) = f.str("snapshot.save") {
            self.save_model = Some(v.to_string());
        }
        if let Some(v) = f.usize("snapshot.checkpoint_every") {
            self.checkpoint_every = v;
        }
        if let Some(v) = f.str("snapshot.resume") {
            self.resume = Some(v.to_string());
        }
        if let Some(v) = f.str("snapshot.warm_start") {
            self.warm_start = Some(v.to_string());
        }
        if let Some(v) = f.bool("distributed.enabled") {
            self.distributed = v;
        }
        if let Some(v) = f.usize("distributed.workers") {
            self.dist_workers = v;
        }
        if let Some(v) = f.str("distributed.listen") {
            self.dist_listen = v.to_string();
        }
        if let Some(v) = f.u64("distributed.timeout_s") {
            self.dist_timeout_s = v;
        }
        if let Some(v) = f.bool("trace.enabled") {
            self.trace_enabled = v;
        }
        if let Some(v) = f.str("trace.path") {
            self.trace_path = Some(v.to_string());
        }
        Ok(())
    }

    /// Whether this run should record trace events at all.
    pub fn tracing(&self) -> bool {
        self.trace_enabled || self.trace_path.is_some()
    }

    /// Resolve the distributed-coordinator knobs into [`DistOptions`].
    pub fn dist_options(&self) -> crate::coordinator::DistOptions {
        crate::coordinator::DistOptions {
            listen: self.dist_listen.clone(),
            workers: self.dist_workers,
            timeout: std::time::Duration::from_secs(self.dist_timeout_s.max(1)),
        }
    }

    /// Resolve the topic-server knobs (`0` serve threads = all cores).
    pub fn serve_options(&self) -> crate::coordinator::ServeOptions {
        crate::coordinator::ServeOptions {
            threads: if self.serve_threads == 0 {
                crate::coordinator::pool::default_threads()
            } else {
                self.serve_threads
            },
            cache_size: self.serve_cache,
        }
    }

    /// The fold-in nonzero budget the served model should enforce:
    /// explicit `foldin_t`, else the training-time `t_v` budget.
    pub fn foldin_budget(&self) -> Option<usize> {
        self.foldin_t.or(self.t_v)
    }

    /// Resolve the sparsity mode string + budgets into the typed enum.
    pub fn sparsity(&self) -> Result<SparsityMode> {
        Ok(match self.sparsity_mode.as_str() {
            "none" | "dense" => SparsityMode::None,
            "both" => SparsityMode::Global {
                t_u: self.t_u,
                t_v: self.t_v,
            },
            "u" => SparsityMode::Global {
                t_u: Some(self.t_u.ok_or_else(|| anyhow::anyhow!("--t-u required for mode u"))?),
                t_v: None,
            },
            "v" => SparsityMode::Global {
                t_u: None,
                t_v: Some(self.t_v.ok_or_else(|| anyhow::anyhow!("--t-v required for mode v"))?),
            },
            "percol" | "per-column" => SparsityMode::PerColumn {
                t_u_col: self.t_u,
                t_v_col: self.t_v,
            },
            "threshold" => {
                anyhow::ensure!(
                    self.tau_u.is_some() || self.tau_v.is_some(),
                    "--tau-u and/or --tau-v required for mode threshold"
                );
                SparsityMode::Threshold {
                    tau_u: self.tau_u,
                    tau_v: self.tau_v,
                }
            }
            other => bail!("unknown sparsity mode {other:?} (none|both|u|v|percol|threshold)"),
        })
    }

    /// Resolve the objective string into the typed enum, refusing
    /// combinations no solver implements: the sequential algorithm and
    /// the XLA backend are Frobenius-only.
    pub fn objective(&self) -> Result<ObjectiveKind> {
        let o = ObjectiveKind::parse(&self.objective).ok_or_else(|| {
            anyhow::anyhow!("unknown objective {:?} (frobenius|kl)", self.objective)
        })?;
        if o == ObjectiveKind::Kl {
            anyhow::ensure!(
                self.algorithm == Algorithm::Als,
                "--objective kl requires --algorithm als (the sequential solver is frobenius-only)"
            );
            anyhow::ensure!(
                self.backend == BackendKind::Native,
                "--objective kl requires --backend native (the xla backend is frobenius-only)"
            );
        }
        Ok(o)
    }

    pub fn nmf_options(&self) -> Result<NmfOptions> {
        let mut opts = NmfOptions::new(self.k)
            .with_iters(self.iters)
            .with_seed(self.seed)
            .with_tol(self.tol)
            .with_sparsity(self.sparsity()?)
            .with_track_error(self.track_error)
            .with_threads(self.threads)
            .with_block_rows(self.block_rows)
            .with_objective(self.objective()?);
        opts.tie_mode = TieMode::KeepTies;
        opts.init_nnz = self.init_nnz;
        if self.checkpoint_every > 0 {
            let path = self.save_model.as_ref().ok_or_else(|| {
                anyhow::anyhow!("--checkpoint-every requires --save-model <path> (the checkpoint destination)")
            })?;
            opts = opts.with_checkpoint(path, self.checkpoint_every);
        }
        Ok(opts)
    }

    pub fn sequential_options(&self) -> SequentialOptions {
        let blocks = self.k / self.block_topics.max(1);
        let mut s = SequentialOptions::new(blocks.max(1), self.iters_per_block);
        s.block_topics = self.block_topics.max(1);
        s.seed = self.seed;
        s.init_nnz = self.init_nnz;
        s.t_u = self.t_u;
        s.t_v = self.t_v;
        // the streamed half-steps honor the same machine-local knobs as
        // Algorithm 2 (bit-identical at any setting)
        s.threads = self.threads;
        s.block_rows = self.block_rows;
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn file_overlay() {
        let f = ConfigFile::parse(
            "corpus = pubmed\nscale = tiny\nseed = 7\nalgorithm = seq\n[nmf]\nk = 3\n[sparsity]\nmode = both\nt_u = 40\nt_v = 80\n",
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_file(&f).unwrap();
        assert_eq!(cfg.corpus, "pubmed");
        assert_eq!(cfg.scale, Scale::Tiny);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.algorithm, Algorithm::Sequential);
        assert_eq!(cfg.k, 3);
        assert_eq!(
            cfg.sparsity().unwrap(),
            SparsityMode::Global {
                t_u: Some(40),
                t_v: Some(80)
            }
        );
    }

    #[test]
    fn sparsity_mode_validation() {
        let mut cfg = RunConfig::default();
        cfg.sparsity_mode = "u".into();
        assert!(cfg.sparsity().is_err()); // missing t_u
        cfg.t_u = Some(10);
        assert_eq!(
            cfg.sparsity().unwrap(),
            SparsityMode::Global {
                t_u: Some(10),
                t_v: None
            }
        );
        cfg.sparsity_mode = "bogus".into();
        assert!(cfg.sparsity().is_err());
    }

    #[test]
    fn nmf_options_roundtrip() {
        let mut cfg = RunConfig::default();
        cfg.k = 4;
        cfg.iters = 10;
        cfg.init_nnz = Some(20);
        let o = cfg.nmf_options().unwrap();
        assert_eq!(o.k, 4);
        assert_eq!(o.max_iters, 10);
        assert_eq!(o.init_nnz, Some(20));
        // default threads = auto → all available cores
        assert_eq!(o.threads, crate::coordinator::pool::default_threads());
    }

    #[test]
    fn threads_knob_from_file() {
        let f = ConfigFile::parse("[nmf]\nthreads = 3\n").unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_file(&f).unwrap();
        assert_eq!(cfg.nmf_options().unwrap().threads, 3);
        let f = ConfigFile::parse("[nmf]\nthreads = auto\n").unwrap();
        let mut cfg = RunConfig::default();
        cfg.threads = 5; // overridden back to auto by the file
        cfg.apply_file(&f).unwrap();
        assert_eq!(
            cfg.nmf_options().unwrap().threads,
            crate::coordinator::pool::default_threads()
        );
    }

    #[test]
    fn block_rows_knob_from_file() {
        let f = ConfigFile::parse("[nmf]\nblock_rows = 512\n").unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_file(&f).unwrap();
        assert_eq!(cfg.block_rows, 512);
        let opts = cfg.nmf_options().unwrap();
        assert_eq!(opts.block_rows, 512);
        assert_eq!(opts.resolved_block_rows(), 512);
        // auto resets an earlier explicit value
        let f = ConfigFile::parse("[nmf]\nblock_rows = auto\n").unwrap();
        let mut cfg = RunConfig::default();
        cfg.block_rows = 64;
        cfg.apply_file(&f).unwrap();
        assert_eq!(cfg.nmf_options().unwrap().block_rows, 0);
    }

    #[test]
    fn serve_knobs_from_file() {
        let f = ConfigFile::parse(
            "[serve]\nthreads = 4\ncache_size = 128\nfoldin_t = 3\n",
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_file(&f).unwrap();
        let opts = cfg.serve_options();
        assert_eq!(opts.threads, 4);
        assert_eq!(opts.cache_size, 128);
        assert_eq!(cfg.foldin_budget(), Some(3));
        // threads = auto resolves to the machine's cores
        let f = ConfigFile::parse("[serve]\nthreads = auto\n").unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_file(&f).unwrap();
        assert_eq!(
            cfg.serve_options().threads,
            crate::coordinator::pool::default_threads()
        );
    }

    #[test]
    fn admin_knobs_from_file() {
        let f = ConfigFile::parse("[serve]\nadmin_port = 9090\nwatch_model = true\n").unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_file(&f).unwrap();
        assert_eq!(cfg.admin_port, Some(9090));
        assert!(cfg.watch_model);
        // defaults: no admin listener, no watcher
        let cfg = RunConfig::default();
        assert_eq!(cfg.admin_port, None);
        assert!(!cfg.watch_model);
        // out-of-range ports are refused, not truncated
        let f = ConfigFile::parse("[serve]\nadmin_port = 70000\n").unwrap();
        let mut cfg = RunConfig::default();
        assert!(cfg.apply_file(&f).is_err());
    }

    #[test]
    fn trace_knobs_from_file() {
        let f = ConfigFile::parse("[trace]\npath = run.trace.jsonl\n").unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_file(&f).unwrap();
        assert_eq!(cfg.trace_path.as_deref(), Some("run.trace.jsonl"));
        assert!(cfg.tracing(), "a path implies tracing");
        // ring-only tracing, no sink
        let f = ConfigFile::parse("[trace]\nenabled = true\n").unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_file(&f).unwrap();
        assert!(cfg.trace_enabled && cfg.trace_path.is_none());
        assert!(cfg.tracing());
        // default: off
        assert!(!RunConfig::default().tracing());
    }

    #[test]
    fn foldin_budget_falls_back_to_t_v() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.foldin_budget(), None);
        cfg.t_v = Some(40);
        assert_eq!(cfg.foldin_budget(), Some(40));
        cfg.foldin_t = Some(7);
        assert_eq!(cfg.foldin_budget(), Some(7));
    }

    #[test]
    fn serve_defaults_track_serve_options() {
        let cfg = RunConfig::default();
        let opts = cfg.serve_options();
        let want = crate::coordinator::ServeOptions::default();
        assert_eq!(opts.threads, want.threads);
        assert_eq!(opts.cache_size, want.cache_size);
    }

    #[test]
    fn corpus_store_knob_from_file() {
        let f = ConfigFile::parse("[corpus]\nstore = corpora/reuters.estdm\n").unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_file(&f).unwrap();
        assert_eq!(cfg.corpus_store.as_deref(), Some("corpora/reuters.estdm"));
        // a top-level corpus preset and a [corpus] section coexist
        let f = ConfigFile::parse("corpus = pubmed\n[corpus]\nstore = x.estdm\n").unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_file(&f).unwrap();
        assert_eq!(cfg.corpus, "pubmed");
        assert_eq!(cfg.corpus_store.as_deref(), Some("x.estdm"));
    }

    #[test]
    fn snapshot_knobs_from_file() {
        let f = ConfigFile::parse(
            "[snapshot]\nsave = model.esnmf\ncheckpoint_every = 10\nresume = ck.esnmf\nwarm_start = old.esnmf\n[serve]\nmodel = served.esnmf\n",
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_file(&f).unwrap();
        assert_eq!(cfg.save_model.as_deref(), Some("model.esnmf"));
        assert_eq!(cfg.checkpoint_every, 10);
        assert_eq!(cfg.resume.as_deref(), Some("ck.esnmf"));
        assert_eq!(cfg.warm_start.as_deref(), Some("old.esnmf"));
        assert_eq!(cfg.model.as_deref(), Some("served.esnmf"));
        let opts = cfg.nmf_options().unwrap();
        assert_eq!(opts.checkpoint_every, 10);
        assert_eq!(
            opts.checkpoint_path.as_deref(),
            Some(std::path::Path::new("model.esnmf"))
        );
    }

    #[test]
    fn checkpoint_without_destination_is_an_error() {
        let mut cfg = RunConfig::default();
        cfg.checkpoint_every = 5;
        let err = cfg.nmf_options().unwrap_err();
        assert!(format!("{err:#}").contains("--save-model"), "{err:#}");
        cfg.save_model = Some("x.esnmf".into());
        assert!(cfg.nmf_options().is_ok());
    }

    #[test]
    fn distributed_knobs_from_file() {
        let f = ConfigFile::parse(
            "[distributed]\nenabled = true\nworkers = 3\nlisten = 127.0.0.1:9100\ntimeout_s = 5\n",
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_file(&f).unwrap();
        assert!(cfg.distributed);
        let d = cfg.dist_options();
        assert_eq!(d.workers, 3);
        assert_eq!(d.listen, "127.0.0.1:9100");
        assert_eq!(d.timeout, std::time::Duration::from_secs(5));
        // defaults: off, 2 workers, the documented port
        let cfg = RunConfig::default();
        assert!(!cfg.distributed);
        assert_eq!(cfg.dist_options().workers, 2);
        assert_eq!(cfg.dist_options().listen, "127.0.0.1:7611");
    }

    #[test]
    fn objective_knob_from_file() {
        let f = ConfigFile::parse("[nmf]\nobjective = kl\n").unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_file(&f).unwrap();
        assert_eq!(cfg.nmf_options().unwrap().objective, ObjectiveKind::Kl);
        // default is the paper's Frobenius math
        let cfg = RunConfig::default();
        assert_eq!(
            cfg.nmf_options().unwrap().objective,
            ObjectiveKind::Frobenius
        );
        // unknown names are refused, not defaulted
        let mut cfg = RunConfig::default();
        cfg.objective = "itakura".into();
        let err = cfg.nmf_options().unwrap_err();
        assert!(format!("{err:#}").contains("objective"), "{err:#}");
    }

    #[test]
    fn kl_requires_the_native_als_path() {
        let mut cfg = RunConfig::default();
        cfg.objective = "kl".into();
        assert!(cfg.objective().is_ok());
        cfg.algorithm = Algorithm::Sequential;
        let err = cfg.objective().unwrap_err();
        assert!(format!("{err:#}").contains("sequential"), "{err:#}");
        cfg.algorithm = Algorithm::Als;
        cfg.backend = BackendKind::Xla;
        let err = cfg.objective().unwrap_err();
        assert!(format!("{err:#}").contains("xla"), "{err:#}");
    }

    #[test]
    fn sequential_options_blocks() {
        let mut cfg = RunConfig::default();
        cfg.k = 6;
        cfg.block_topics = 2;
        cfg.iters_per_block = 5;
        let s = cfg.sequential_options();
        assert_eq!(s.blocks, 3);
        assert_eq!(s.total_k(), 6);
    }
}
