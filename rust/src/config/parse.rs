//! The TOML-subset parser.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as u64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed file: `section.key` → value ("" section for top-level keys).
#[derive(Clone, Debug, Default)]
pub struct ConfigFile {
    pub entries: BTreeMap<String, Value>,
}

impl ConfigFile {
    pub fn parse(text: &str) -> Result<ConfigFile, String> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                if section.is_empty() {
                    return Err(format!("line {}: empty section name", lineno + 1));
                }
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let full_key = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            let value = parse_value(value.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            entries.insert(full_key, value);
        }
        Ok(ConfigFile { entries })
    }

    pub fn load(path: &std::path::Path) -> Result<ConfigFile, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        ConfigFile::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(|v| v.as_str())
    }

    pub fn usize(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(|v| v.as_usize())
    }

    pub fn u64(&self, key: &str) -> Option<u64> {
        self.get(key).and_then(|v| v.as_u64())
    }

    pub fn f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|v| v.as_f64())
    }

    pub fn bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(|v| v.as_bool())
    }

    /// An `N | auto` knob (worker counts, block heights): a non-negative
    /// integer, or the bare word `auto` (→ 0, "let the solver decide").
    pub fn auto_usize(&self, key: &str) -> Option<usize> {
        match self.get(key)? {
            Value::Str(s) if s == "auto" => Some(0),
            v => v.as_usize(),
        }
    }

    /// [`Self::auto_usize`] under its historical worker-count name.
    pub fn threads(&self, key: &str) -> Option<usize> {
        self.auto_usize(key)
    }
}

fn strip_comment(line: &str) -> &str {
    // a # inside a quoted string is preserved
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    // bare words count as strings (convenient for presets — corpus =
    // reuters — and for paths: resume = checkpoints/run1.esnmf)
    if s.chars().all(|c| c.is_alphanumeric() || "-_.:/".contains(c)) {
        return Ok(Value::Str(s.to_string()));
    }
    Err(format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# run configuration
corpus = reuters
scale = "tiny"

[nmf]
k = 5
iters = 75
tol = 1e-8
track_error = true

[sparsity]
mode = both
t_u = 55

[serve]
threads = auto
cache_size = 512
foldin_t = 10
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = ConfigFile::parse(SAMPLE).unwrap();
        assert_eq!(c.str("corpus"), Some("reuters"));
        assert_eq!(c.str("scale"), Some("tiny"));
        assert_eq!(c.usize("nmf.k"), Some(5));
        assert_eq!(c.f64("nmf.tol"), Some(1e-8));
        assert_eq!(c.bool("nmf.track_error"), Some(true));
        assert_eq!(c.usize("sparsity.t_u"), Some(55));
        assert_eq!(c.str("sparsity.mode"), Some("both"));
        assert_eq!(c.threads("serve.threads"), Some(0)); // auto
        assert_eq!(c.usize("serve.cache_size"), Some(512));
        assert_eq!(c.usize("serve.foldin_t"), Some(10));
    }

    #[test]
    fn comments_and_blank_lines() {
        let c = ConfigFile::parse("a = 1 # trailing\n\n# full line\nb = \"x # y\"\n").unwrap();
        assert_eq!(c.usize("a"), Some(1));
        assert_eq!(c.str("b"), Some("x # y"));
    }

    #[test]
    fn errors() {
        assert!(ConfigFile::parse("[]\n").is_err());
        assert!(ConfigFile::parse("novalue\n").is_err());
        assert!(ConfigFile::parse("x = @@@\n").is_err());
        assert!(ConfigFile::parse(" = 5\n").is_err());
    }

    #[test]
    fn threads_accepts_auto_and_integers() {
        let c = ConfigFile::parse("[nmf]\nthreads = auto\n[other]\nthreads = 4\n").unwrap();
        assert_eq!(c.threads("nmf.threads"), Some(0));
        assert_eq!(c.threads("other.threads"), Some(4));
        assert_eq!(c.threads("missing.threads"), None);
    }

    #[test]
    fn auto_usize_serves_block_rows() {
        let c = ConfigFile::parse("[nmf]\nblock_rows = auto\n[big]\nblock_rows = 4096\n")
            .unwrap();
        assert_eq!(c.auto_usize("nmf.block_rows"), Some(0));
        assert_eq!(c.auto_usize("big.block_rows"), Some(4096));
        assert_eq!(c.auto_usize("missing.block_rows"), None);
        // non-`auto` words do not parse as a knob value
        let c = ConfigFile::parse("[nmf]\nblock_rows = lots\n").unwrap();
        assert_eq!(c.auto_usize("nmf.block_rows"), None);
    }

    #[test]
    fn bare_paths_parse_as_strings() {
        let c = ConfigFile::parse(
            "[snapshot]\nsave = models/run1.esnmf\nresume = ../ck/iter40.esnmf\n",
        )
        .unwrap();
        assert_eq!(c.str("snapshot.save"), Some("models/run1.esnmf"));
        assert_eq!(c.str("snapshot.resume"), Some("../ck/iter40.esnmf"));
    }

    #[test]
    fn negative_and_float_values() {
        let c = ConfigFile::parse("x = -3\ny = 2.5\n").unwrap();
        assert_eq!(c.get("x"), Some(&Value::Int(-3)));
        assert_eq!(c.f64("y"), Some(2.5));
        assert_eq!(c.usize("x"), None); // negative rejects usize view
    }
}
