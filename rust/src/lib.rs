//! # esnmf — Enforced Sparse Non-Negative Matrix Factorization
//!
//! A production-shaped reproduction of *"Enforced Sparse Non-Negative
//! Matrix Factorization"* (Gavin, Gadepally, Kepner; IPDPSW 2016,
//! DOI 10.1109/IPDPSW.2016.58) as a three-layer rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordinator: corpus ingestion, the
//!   sparse-matrix substrate, the four NMF solvers of the paper
//!   (projected ALS, enforced-sparsity ALS, column-wise enforcement,
//!   sequential ALS), evaluation, job scheduling, a topic-query server,
//!   and the experiment harness that regenerates every figure/table.
//! * **Layer 2** — a JAX compute graph (one fused ALS iteration) lowered
//!   once at build time to HLO text artifacts (`python/compile/`).
//! * **Layer 1** — Pallas kernels for the ALS hot spots, embedded in the
//!   Layer-2 graph (`python/compile/kernels/`).
//!
//! Python never runs on the request path: [`runtime`] loads the AOT
//! artifacts through PJRT and [`backend::XlaBackend`] drives them from
//! rust. The [`backend::NativeBackend`] implements the same iteration over
//! the sparse substrate — that is where the paper's memory claims live.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod backend;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod corpus;
pub mod dense;
pub mod error;
pub mod eval;
pub mod experiments;
pub mod io;
pub mod nmf;
pub mod runtime;
pub mod sparse;
pub mod text;
pub mod util;

/// Crate-wide result type for internals that have not adopted the typed
/// surface; the CLI boundary and the distributed plane use
/// [`EsnmfError`] (every `anyhow` error converts in via `From`).
pub type Result<T> = anyhow::Result<T>;

pub use error::EsnmfError;
