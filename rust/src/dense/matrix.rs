//! Row-major dense matrix with the handful of ops the NMF engine needs.

#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for l in 0..self.cols {
                let a = self.at(i, l);
                if a != 0.0 {
                    let orow = other.row(l);
                    let out_row =
                        &mut out.data[i * other.cols..(i + 1) * other.cols];
                    for (o, &b) in out_row.iter_mut().zip(orow) {
                        *o += a * b;
                    }
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.at(r, c);
            }
        }
        out
    }

    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self.at(i, i) as f64).sum()
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eye_and_matmul() {
        let a = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let i3 = Mat::eye(3);
        assert_eq!(a.matmul(&i3), a);
    }

    #[test]
    fn matmul_values() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        assert_eq!(a.matmul(&b).data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().at(2, 1), 6.0);
    }

    #[test]
    fn trace_and_diff() {
        let a = Mat::from_vec(2, 2, vec![1.0, 9.0, 9.0, 2.0]);
        assert_eq!(a.trace(), 3.0);
        let b = Mat::from_vec(2, 2, vec![1.0, 9.5, 9.0, 2.0]);
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-7);
    }
}
