//! Small dense linear algebra: the (k, k) normal-equation solves of ALS.
//!
//! k is the topic count (≤ 64 in every experiment), so these are tiny
//! matrices — no BLAS needed, but correctness and the exact regularization
//! must match the Layer-2 JAX graph (`python/compile/model.py`) so the
//! native and XLA backends produce interchangeable iterates.

pub mod matrix;
pub mod solve;

pub use matrix::Mat;
pub use solve::{inverse_spd, RIDGE_SCALE};
