//! SPD inverse for the (k, k) Gram matrices, with the same trace-scaled
//! ridge as the Layer-2 JAX graph.
//!
//! `python/compile/model.py` inverts `S + εI` with
//! `ε = RIDGE_SCALE·tr(S)/k + 1e-10`; we invert the identical matrix (via
//! Cholesky, which is exact for SPD inputs), so the two backends produce
//! the same ALS iterates to float tolerance. Keep `RIDGE_SCALE` in sync.

use super::matrix::Mat;

/// Must equal `model.RIDGE_SCALE` on the python side.
pub const RIDGE_SCALE: f64 = 1e-6;

/// Cholesky factorization of an SPD matrix: returns lower-triangular L
/// with A = L·Lᵀ, or None if a pivot is non-positive.
pub fn cholesky(a: &Mat) -> Option<Mat> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j) as f64;
            for p in 0..j {
                sum -= l.at(i, p) as f64 * l.at(j, p) as f64;
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                *l.at_mut(i, j) = sum.sqrt() as f32;
            } else {
                *l.at_mut(i, j) = (sum / l.at(j, j) as f64) as f32;
            }
        }
    }
    Some(l)
}

/// Solve L·y = b then Lᵀ·x = y in place of b.
fn cholesky_solve_vec(l: &Mat, b: &mut [f32]) {
    let n = l.rows;
    // forward substitution
    for i in 0..n {
        let mut sum = b[i] as f64;
        for p in 0..i {
            sum -= l.at(i, p) as f64 * b[p] as f64;
        }
        b[i] = (sum / l.at(i, i) as f64) as f32;
    }
    // backward substitution with Lᵀ
    for i in (0..n).rev() {
        let mut sum = b[i] as f64;
        for p in i + 1..n {
            sum -= l.at(p, i) as f64 * b[p] as f64;
        }
        b[i] = (sum / l.at(i, i) as f64) as f32;
    }
}

/// Inverse of the ridged Gram matrix `S + εI` (row-major (k,k) input and
/// output). Never fails: the ridge makes the matrix strictly SPD even when
/// topics are empty (S singular or zero).
pub fn inverse_spd(s: &[f32], k: usize) -> Vec<f32> {
    assert_eq!(s.len(), k * k);
    let trace: f64 = (0..k).map(|i| s[i * k + i] as f64).sum();
    let eps = (RIDGE_SCALE * trace / k as f64 + 1e-10) as f32;
    let mut a = Mat::from_vec(k, k, s.to_vec());
    for i in 0..k {
        *a.at_mut(i, i) += eps;
    }
    let l = cholesky(&a).unwrap_or_else(|| {
        // pathological float cancellation: fall back to a heavier ridge
        let mut a2 = a.clone();
        let bump = (trace / k as f64 * 1e-3 + 1e-6) as f32;
        for i in 0..k {
            *a2.at_mut(i, i) += bump;
        }
        cholesky(&a2).expect("Cholesky failed even with heavy ridge")
    });
    let mut inv = vec![0.0f32; k * k];
    let mut col = vec![0.0f32; k];
    for j in 0..k {
        col.iter_mut().for_each(|x| *x = 0.0);
        col[j] = 1.0;
        cholesky_solve_vec(&l, &mut col);
        for i in 0..k {
            inv[i * k + j] = col[i];
        }
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_spd(rng: &mut Rng, k: usize) -> Vec<f32> {
        // X (k+3, k) → XᵀX is SPD almost surely
        let n = k + 3;
        let x: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
        let mut s = vec![0.0f32; k * k];
        for r in 0..n {
            for i in 0..k {
                for j in 0..k {
                    s[i * k + j] += x[r * k + i] * x[r * k + j];
                }
            }
        }
        s
    }

    #[test]
    fn inverse_times_original_is_identity() {
        prop::check("spd-inverse", 1000, 48, |rng: &mut Rng| {
            let k = rng.range(1, 10);
            let s = random_spd(rng, k);
            let inv = inverse_spd(&s, k);
            // (S + eps I) * inv ≈ I; eps is tiny relative to trace
            let trace: f64 = (0..k).map(|i| s[i * k + i] as f64).sum();
            let eps = (RIDGE_SCALE * trace / k as f64 + 1e-10) as f32;
            let mut sr = s.clone();
            for i in 0..k {
                sr[i * k + i] += eps;
            }
            let prod = Mat::from_vec(k, k, sr).matmul(&Mat::from_vec(k, k, inv));
            let err = prod.max_abs_diff(&Mat::eye(k));
            assert!(err < 1e-2, "k={k} err={err}");
        });
    }

    #[test]
    fn survives_zero_matrix() {
        let inv = inverse_spd(&[0.0; 9], 3);
        assert!(inv.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn survives_rank_deficiency() {
        // rank-1: s = v vᵀ with v = (1, 2)
        let s = [1.0, 2.0, 2.0, 4.0];
        let inv = inverse_spd(&s, 2);
        assert!(inv.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn scalar_case() {
        let inv = inverse_spd(&[4.0], 1);
        assert!((inv[0] - 0.25).abs() < 1e-3);
    }
}
