//! Wall-clock timing helpers.

use std::time::{Duration, Instant};

/// A simple start/lap timer.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
    last: Instant,
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

impl Timer {
    pub fn start() -> Self {
        let now = Instant::now();
        Timer { start: now, last: now }
    }

    /// Seconds since construction.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Seconds since the previous `lap()` (or construction).
    pub fn lap_s(&mut self) -> f64 {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        dt
    }
}

/// Time a closure, returning (result, seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

/// Human formatting: 1.2345 s / 12.3 ms / 45.6 µs.
pub fn fmt_seconds(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2} µs", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let mut t = Timer::start();
        let a = t.lap_s();
        let b = t.elapsed_s();
        assert!(a >= 0.0 && b >= a);
    }

    #[test]
    fn time_returns_value() {
        let (v, s) = time(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_seconds(2.0), "2.000 s");
        assert_eq!(fmt_seconds(0.0123), "12.30 ms");
        assert_eq!(fmt_seconds(12.3e-6), "12.30 µs");
        assert_eq!(fmt_seconds(5e-9), "5 ns");
    }
}
