//! Deterministic PRNG: splitmix64 seeding a xoshiro256++ core.
//!
//! Every stochastic component in the library (corpus generation, factor
//! initialization, property tests) takes an explicit [`Rng`] so runs are
//! reproducible from a single `u64` seed recorded in experiment output.

/// xoshiro256++ with splitmix64 seeding. Not cryptographic.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a single integer.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, bound)` (Lemire's method).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "below(0)");
        let bound = bound as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// |N(0,1)| as f32 — the paper initializes factors with nonnegative noise.
    pub fn abs_normal_f32(&mut self) -> f32 {
        self.normal().abs() as f32
    }

    /// Sample from an unnormalized discrete distribution by CDF walk.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical with zero mass");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `count` distinct indices from `[0, n)` (floyd's algorithm-ish via shuffle
    /// for small n, rejection for large sparse draws).
    pub fn sample_distinct(&mut self, n: usize, count: usize) -> Vec<usize> {
        assert!(count <= n, "sample_distinct({n}, {count})");
        if count * 3 > n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(count);
            all.sort_unstable();
            all
        } else {
            let mut seen = std::collections::HashSet::with_capacity(count * 2);
            let mut out = Vec::with_capacity(count);
            while out.len() < count {
                let x = self.below(n);
                if seen.insert(x) {
                    out.push(x);
                }
            }
            out.sort_unstable();
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_enough() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Rng::new(11);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(13);
        let mut hits = [0usize; 3];
        for _ in 0..30_000 {
            hits[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(hits[2] > hits[1] && hits[1] > hits[0], "{hits:?}");
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Rng::new(17);
        for &(n, c) in &[(10usize, 10usize), (100, 7), (1000, 50), (5, 0)] {
            let s = r.sample_distinct(n, c);
            assert_eq!(s.len(), c);
            let mut dedup = s.clone();
            dedup.dedup();
            assert_eq!(dedup.len(), c, "duplicates in {s:?}");
            assert!(s.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic]
    fn below_zero_panics() {
        Rng::new(0).below(0);
    }
}
