//! Small self-contained substrates: PRNG, statistics, timing, logging,
//! JSON, and a mini property-testing harness.
//!
//! The build is fully offline (only `xla` + `anyhow` are vendored), so the
//! usual ecosystem crates (`rand`, `serde_json`, `proptest`, `criterion`)
//! are reimplemented here at the scale this project needs.

pub mod bench;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;
pub mod trace;

pub use rng::Rng;
pub use timer::Timer;
