//! A miniature benchmark harness (criterion is unavailable offline).
//!
//! Each `cargo bench` target builds a [`BenchSuite`], registers closures,
//! and calls [`BenchSuite::run`], which warms up, measures a configurable
//! number of timed samples, and prints a criterion-style summary line plus
//! the paper-table rows the target exists to regenerate. Honors
//! `ESNMF_BENCH_SAMPLES` and `ESNMF_BENCH_FAST=1` (CI smoke mode).

use super::stats;
use super::timer::fmt_seconds;
use std::hint::black_box;
use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub samples_s: Vec<f64>,
}

impl BenchResult {
    pub fn median_s(&self) -> f64 {
        stats::median(&self.samples_s)
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<44} median {:>12}  mean {:>12}  sd {:>10}  (n={})",
            self.name,
            fmt_seconds(stats::median(&self.samples_s)),
            fmt_seconds(stats::mean(&self.samples_s)),
            fmt_seconds(stats::stddev(&self.samples_s)),
            self.samples_s.len()
        )
    }
}

pub struct BenchSuite {
    pub title: String,
    pub samples: usize,
    pub warmup: usize,
    pub results: Vec<BenchResult>,
}

pub fn fast_mode() -> bool {
    std::env::var("ESNMF_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

impl BenchSuite {
    pub fn new(title: &str) -> Self {
        let mut samples = std::env::var("ESNMF_BENCH_SAMPLES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(10);
        let mut warmup = 2;
        if fast_mode() {
            samples = 2;
            warmup = 0;
        }
        println!("=== bench: {title} (samples={samples}) ===");
        BenchSuite {
            title: title.to_string(),
            samples,
            warmup,
            results: Vec::new(),
        }
    }

    /// Measure `f` (the closure's result is black-boxed).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples_s = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            black_box(f());
            samples_s.push(t.elapsed().as_secs_f64());
        }
        let result = BenchResult {
            name: name.to_string(),
            samples_s,
        };
        println!("{}", result.summary());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Print a markdown-ish table header for paper rows.
    pub fn table(&self, header: &str) {
        println!("\n--- {}: {header} ---", self.title);
    }

    pub fn row(&self, cells: &[String]) {
        println!("{}", cells.join(" | "));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        std::env::set_var("ESNMF_BENCH_FAST", "1");
        let mut suite = BenchSuite::new("selftest");
        let r = suite.bench("noop-ish", || (0..1000u64).sum::<u64>());
        assert_eq!(r.samples_s.len(), 2);
        assert!(r.median_s() >= 0.0);
        std::env::remove_var("ESNMF_BENCH_FAST");
    }
}
