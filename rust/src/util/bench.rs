//! A miniature benchmark harness (criterion is unavailable offline).
//!
//! Each `cargo bench` target builds a [`BenchSuite`], registers closures,
//! and calls [`BenchSuite::bench`], which warms up, measures a
//! configurable number of timed samples, and prints a criterion-style
//! summary line plus the paper-table rows the target exists to
//! regenerate. Environment knobs:
//!
//! * `ESNMF_BENCH_SAMPLES=N` — timed samples per bench (default 10).
//! * `ESNMF_BENCH_FAST=1` — 2 samples, no warmup, tiny problem sizes.
//! * `BENCH_SMOKE=1` — CI smoke mode: 1 sample, no warmup, forces tiny
//!   sizes (implies fast mode), so every bench target doubles as a
//!   can-it-still-run regression check.
//! * `ESNMF_BENCH_JSON=<dir>` — on drop, each suite writes its results
//!   as `<dir>/<slug-of-title>.json` (machine-readable; CI uploads these
//!   as workflow artifacts).
//! * `ESNMF_BENCH_COMBINED=<file>` — on drop, each suite also merges its
//!   results into one accumulating JSON file keyed by suite slug (CI
//!   points this at `BENCH_smoke.json` in the repository root, so every
//!   PR's smoke run produces one comparable perf-trajectory document).

use super::json::Json;
use super::stats;
use super::timer::fmt_seconds;
use std::collections::BTreeMap;
use std::hint::black_box;
use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub samples_s: Vec<f64>,
}

impl BenchResult {
    pub fn median_s(&self) -> f64 {
        stats::median(&self.samples_s)
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<44} median {:>12}  mean {:>12}  sd {:>10}  (n={})",
            self.name,
            fmt_seconds(stats::median(&self.samples_s)),
            fmt_seconds(stats::mean(&self.samples_s)),
            fmt_seconds(stats::stddev(&self.samples_s)),
            self.samples_s.len()
        )
    }
}

pub struct BenchSuite {
    pub title: String,
    pub samples: usize,
    pub warmup: usize,
    pub results: Vec<BenchResult>,
    /// Named scalar observations (memory peaks, speedup ratios, …)
    /// carried alongside the timings in every JSON emission — this is
    /// how the fig6 suite gives the perf trajectory a memory axis.
    pub metrics: BTreeMap<String, f64>,
}

/// CI smoke mode: a single rep over tiny sizes (see the module docs).
pub fn smoke_mode() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

pub fn fast_mode() -> bool {
    smoke_mode()
        || std::env::var("ESNMF_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

impl BenchSuite {
    pub fn new(title: &str) -> Self {
        let mut samples = std::env::var("ESNMF_BENCH_SAMPLES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(10);
        let mut warmup = 2;
        if smoke_mode() {
            samples = 1;
            warmup = 0;
        } else if fast_mode() {
            samples = 2;
            warmup = 0;
        }
        println!("=== bench: {title} (samples={samples}) ===");
        BenchSuite {
            title: title.to_string(),
            samples,
            warmup,
            results: Vec::new(),
            metrics: BTreeMap::new(),
        }
    }

    /// Record a named scalar observation (printed immediately, emitted
    /// under `"metrics"` in the suite JSON and the combined trajectory).
    pub fn metric(&mut self, name: &str, value: f64) {
        println!("metric {name} = {value}");
        self.metrics.insert(name.to_string(), value);
    }

    /// Measure `f` (the closure's result is black-boxed).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples_s = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            black_box(f());
            samples_s.push(t.elapsed().as_secs_f64());
        }
        let result = BenchResult {
            name: name.to_string(),
            samples_s,
        };
        println!("{}", result.summary());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Print a markdown-ish table header for paper rows.
    pub fn table(&self, header: &str) {
        println!("\n--- {}: {header} ---", self.title);
    }

    pub fn row(&self, cells: &[String]) {
        println!("{}", cells.join(" | "));
    }

    /// Machine-readable form of every result in this suite.
    pub fn to_json(&self) -> Json {
        let results: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                let mut obj = BTreeMap::new();
                obj.insert("name".to_string(), Json::Str(r.name.clone()));
                obj.insert("median_s".to_string(), Json::Num(r.median_s()));
                obj.insert(
                    "mean_s".to_string(),
                    Json::Num(stats::mean(&r.samples_s)),
                );
                obj.insert(
                    "samples_s".to_string(),
                    Json::Arr(r.samples_s.iter().map(|&s| Json::Num(s)).collect()),
                );
                Json::Obj(obj)
            })
            .collect();
        let mut obj = BTreeMap::new();
        obj.insert("title".to_string(), Json::Str(self.title.clone()));
        obj.insert("samples".to_string(), Json::Num(self.samples as f64));
        obj.insert(
            "smoke".to_string(),
            Json::Bool(smoke_mode()),
        );
        let mut metrics: BTreeMap<String, Json> = self
            .metrics
            .iter()
            .map(|(k, &v)| (k.clone(), Json::Num(v)))
            .collect();
        // every result's wall-time median rides along as a metric
        // (`wall_s_<result slug>`), so `esnmf bench-check --guards
        // wall_s` turns the smoke trajectory into a wall-time
        // regression gate without each bench target opting in
        for r in &self.results {
            metrics.insert(format!("wall_s_{}", slug_of(&r.name)), Json::Num(r.median_s()));
        }
        obj.insert("metrics".to_string(), Json::Obj(metrics));
        obj.insert("results".to_string(), Json::Arr(results));
        Json::Obj(obj)
    }

    /// Filesystem-safe slug of the suite title.
    pub fn slug(&self) -> String {
        slug_of(&self.title)
    }

    fn emit_json(&self) {
        if self.results.is_empty() {
            return;
        }
        if let Ok(dir) = std::env::var("ESNMF_BENCH_JSON") {
            if !dir.is_empty() {
                if std::fs::create_dir_all(&dir).is_err() {
                    eprintln!("bench: cannot create {dir}; skipping JSON emission");
                } else {
                    let path =
                        std::path::Path::new(&dir).join(format!("{}.json", self.slug()));
                    match std::fs::write(&path, self.to_json().to_string()) {
                        Ok(()) => println!("wrote {}", path.display()),
                        Err(e) => eprintln!("bench: writing {}: {e}", path.display()),
                    }
                }
            }
        }
        if let Ok(file) = std::env::var("ESNMF_BENCH_COMBINED") {
            if !file.is_empty() {
                if let Err(e) = self.merge_into_combined(std::path::Path::new(&file)) {
                    eprintln!("bench: merging into {file}: {e}");
                }
            }
        }
    }

    /// Read-modify-write this suite into the accumulating combined file
    /// (`{"schema": ..., "suites": {<slug>: <suite json>, ...}}`). An
    /// absent or unparsable file starts fresh, so the trajectory document
    /// self-heals.
    fn merge_into_combined(&self, path: &std::path::Path) -> Result<(), String> {
        let mut root = std::fs::read_to_string(path)
            .ok()
            .and_then(|text| Json::parse(&text).ok())
            .filter(|j| matches!(j, Json::Obj(_)))
            .unwrap_or_else(|| Json::Obj(BTreeMap::new()));
        let Json::Obj(obj) = &mut root else { unreachable!() };
        obj.insert(
            "schema".to_string(),
            Json::Str("esnmf-bench-smoke-v1".to_string()),
        );
        let suites = obj
            .entry("suites".to_string())
            .or_insert_with(|| Json::Obj(BTreeMap::new()));
        if !matches!(suites, Json::Obj(_)) {
            *suites = Json::Obj(BTreeMap::new());
        }
        if let Json::Obj(m) = suites {
            m.insert(self.slug(), self.to_json());
        }
        std::fs::write(path, root.to_string()).map_err(|e| e.to_string())?;
        println!("merged suite {:?} into {}", self.slug(), path.display());
        Ok(())
    }
}

impl Drop for BenchSuite {
    fn drop(&mut self) {
        self.emit_json();
    }
}

/// Filesystem- and metric-name-safe slug: lowercase alphanumerics with
/// single `_` separators (shared by suite filenames and the per-result
/// `wall_s_*` metric keys).
fn slug_of(text: &str) -> String {
    let mut out = String::with_capacity(text.len());
    let mut last_sep = true; // trim leading separators
    for c in text.chars() {
        if c.is_ascii_alphanumeric() {
            out.push(c.to_ascii_lowercase());
            last_sep = false;
        } else if !last_sep {
            out.push('_');
            last_sep = true;
        }
    }
    while out.ends_with('_') {
        out.pop();
    }
    if out.is_empty() {
        "bench".to_string()
    } else {
        out
    }
}

/// One guarded metric that moved the wrong way between two trajectory
/// points (see [`metric_regressions`]).
#[derive(Clone, Debug, PartialEq)]
pub struct MetricRegression {
    /// `<suite slug>.<metric name>`
    pub path: String,
    pub previous: f64,
    pub current: f64,
}

/// Compare the *guarded* metrics of two combined trajectory documents
/// (the `BENCH_smoke.json` schema: `{"suites": {<slug>: {"metrics":
/// {...}}}}`): a metric whose name contains any of the `guards`
/// substrings is lower-is-better (memory peaks, resident bytes), and
/// regresses when `current > previous * tolerance`. Metrics absent from
/// either document are skipped — a new metric has no baseline, and a
/// removed one has nothing to regress. This is the CI bench-smoke
/// memory-regression gate (`esnmf bench-check`).
pub fn metric_regressions(
    previous: &Json,
    current: &Json,
    guards: &[&str],
    tolerance: f64,
) -> Vec<MetricRegression> {
    let mut out = Vec::new();
    let Some(Json::Obj(cur_suites)) = current.get("suites") else {
        return out;
    };
    for (slug, suite) in cur_suites {
        let Some(Json::Obj(cur_metrics)) = suite.get("metrics") else {
            continue;
        };
        for (name, value) in cur_metrics {
            if !guards.iter().any(|g| name.contains(g)) {
                continue;
            }
            let Some(cur) = value.as_f64() else { continue };
            let prev = previous
                .get("suites")
                .and_then(|s| s.get(slug))
                .and_then(|s| s.get("metrics"))
                .and_then(|m| m.get(name))
                .and_then(Json::as_f64);
            if let Some(prev) = prev {
                if cur > prev * tolerance {
                    out.push(MetricRegression {
                        path: format!("{slug}.{name}"),
                        previous: prev,
                        current: cur,
                    });
                }
            }
        }
    }
    out
}

/// Check absolute (baseline-free) metric limits against one trajectory
/// document: for each `(name, limit)`, every suite carrying a metric of
/// that exact name must report a value ≤ `limit`, and at least one suite
/// must carry it at all — a missing metric is a violation, not a pass
/// (the trace-overhead gate must fail when the bench silently stopped
/// recording it). Unlike [`metric_regressions`], this needs no previous
/// point, so it still gates when the trajectory cache is cold.
pub fn absolute_violations(current: &Json, limits: &[(String, f64)]) -> Vec<String> {
    let mut out = Vec::new();
    for (name, limit) in limits {
        let mut found = false;
        if let Some(Json::Obj(suites)) = current.get("suites") {
            for (slug, suite) in suites {
                let value = suite
                    .get("metrics")
                    .and_then(|m| m.get(name))
                    .and_then(Json::as_f64);
                if let Some(v) = value {
                    found = true;
                    if v > *limit {
                        out.push(format!("{slug}.{name} = {v} exceeds absolute limit {limit}"));
                    }
                }
            }
        }
        if !found {
            out.push(format!(
                "{name} missing from the current trajectory (absolute limit {limit} cannot gate)"
            ));
        }
    }
    out
}

/// True when a combined trajectory document has no recorded suites at
/// all — the state of the committed `BENCH_smoke.json` seed before the
/// first gated bench run. [`metric_regressions`] against such a baseline
/// is vacuously empty (nothing to compare), so the CLI gate
/// (`esnmf bench-check`) treats it as an explicit "record and pass":
/// the current document becomes the trajectory's first real point
/// instead of silently "passing" a comparison that never happened.
pub fn trajectory_is_empty(doc: &Json) -> bool {
    match doc.get("suites") {
        Some(Json::Obj(suites)) => suites.is_empty(),
        // absent or non-object: nothing recorded under it either way
        _ => true,
    }
}

/// Before/after markdown table over two combined trajectory documents
/// (the `BENCH_smoke.json` schema) — the body of `esnmf bench-compare`
/// and the report `scripts/perf_compare.sh` / `scripts/pgo.sh` emit.
/// Rows cover every metric of `after` whose name contains any of the
/// `guards` substrings (pass `["wall_s"]` for the wall-clock story, or
/// an empty slice for everything); metrics new in `after` are marked
/// `(new)`. `after/before < 1` means the current build is faster on
/// lower-is-better metrics.
pub fn markdown_compare(before: &Json, after: &Json, guards: &[&str]) -> String {
    let mut out = String::new();
    out.push_str("| metric | before | after | after/before |\n");
    out.push_str("|---|---:|---:|---:|\n");
    let Some(Json::Obj(after_suites)) = after.get("suites") else {
        return out;
    };
    for (slug, suite) in after_suites {
        let Some(Json::Obj(metrics)) = suite.get("metrics") else {
            continue;
        };
        for (name, value) in metrics {
            if !guards.is_empty() && !guards.iter().any(|g| name.contains(g)) {
                continue;
            }
            let Some(cur) = value.as_f64() else { continue };
            let prev = before
                .get("suites")
                .and_then(|s| s.get(slug))
                .and_then(|s| s.get("metrics"))
                .and_then(|m| m.get(name))
                .and_then(Json::as_f64);
            let row = match prev {
                Some(p) if p > 0.0 => {
                    format!("| {slug}.{name} | {p:.6} | {cur:.6} | {:.3} |\n", cur / p)
                }
                Some(p) => format!("| {slug}.{name} | {p:.6} | {cur:.6} | n/a |\n"),
                None => format!("| {slug}.{name} | (new) | {cur:.6} | n/a |\n"),
            };
            out.push_str(&row);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        std::env::set_var("ESNMF_BENCH_FAST", "1");
        let mut suite = BenchSuite::new("selftest");
        let r = suite.bench("noop-ish", || (0..1000u64).sum::<u64>());
        assert!(!r.samples_s.is_empty() && r.samples_s.len() <= 2);
        assert!(r.median_s() >= 0.0);
        std::env::remove_var("ESNMF_BENCH_FAST");
    }

    #[test]
    fn slug_is_filesystem_safe() {
        let mut s = BenchSuite::new("micro: sparse kernels");
        assert_eq!(s.slug(), "micro_sparse_kernels");
        s.title = "  --weird?? title!  ".into();
        assert_eq!(s.slug(), "weird_title");
        s.title = "???".into();
        assert_eq!(s.slug(), "bench");
        s.results.clear(); // nothing to emit on drop
    }

    #[test]
    fn json_shape_round_trips() {
        let mut suite = BenchSuite::new("jsontest");
        suite.results.push(BenchResult {
            name: "a".into(),
            samples_s: vec![0.25, 0.5, 0.75],
        });
        suite.metric("max_intermediate_nnz", 160.0);
        let j = suite.to_json();
        assert_eq!(
            Json::parse(&j.to_string())
                .unwrap()
                .get("metrics")
                .and_then(|m| m.get("max_intermediate_nnz"))
                .and_then(Json::as_f64),
            Some(160.0)
        );
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("title").and_then(Json::as_str), Some("jsontest"));
        // each result's median rides along as a wall_s_* metric so the
        // bench-check gate can guard wall time
        assert_eq!(
            parsed
                .get("metrics")
                .and_then(|m| m.get("wall_s_a"))
                .and_then(Json::as_f64),
            Some(0.5)
        );
        let results = parsed.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("name").and_then(Json::as_str), Some("a"));
        assert_eq!(
            results[0].get("median_s").and_then(Json::as_f64),
            Some(0.5)
        );
        assert_eq!(
            results[0]
                .get("samples_s")
                .and_then(Json::as_arr)
                .map(|a| a.len()),
            Some(3)
        );
        suite.results.clear(); // keep the drop hook from writing files
    }

    #[test]
    fn metric_regressions_flag_only_guarded_growth() {
        let doc = |intermediate: f64, resident: f64, time: f64| {
            Json::parse(&format!(
                r#"{{"schema":"esnmf-bench-smoke-v1","suites":{{
                    "fig6":{{"metrics":{{
                        "blocked.max_intermediate_nnz":{intermediate},
                        "store.resident_corpus_peak_bytes":{resident},
                        "wall_s":{time}}}}},
                    "micro":{{"metrics":{{}}}}}}}}"#
            ))
            .unwrap()
        };
        let guards = ["max_intermediate_nnz", "resident_corpus"];
        let prev = doc(100.0, 5000.0, 1.0);
        // within tolerance: no regression (time is unguarded and may grow)
        let ok = doc(105.0, 5200.0, 99.0);
        assert!(metric_regressions(&prev, &ok, &guards, 1.10).is_empty());
        // a guarded metric beyond tolerance is flagged with its path
        let bad = doc(150.0, 5200.0, 1.0);
        let regs = metric_regressions(&prev, &bad, &guards, 1.10);
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].path, "fig6.blocked.max_intermediate_nnz");
        assert_eq!(regs[0].previous, 100.0);
        assert_eq!(regs[0].current, 150.0);
        // both guarded metrics regressing are both reported
        let worse = doc(150.0, 9000.0, 1.0);
        assert_eq!(metric_regressions(&prev, &worse, &guards, 1.10).len(), 2);
        // a brand-new metric (absent from prev) has no baseline → skipped
        let empty_prev = Json::parse(r#"{"suites":{}}"#).unwrap();
        assert!(metric_regressions(&empty_prev, &bad, &guards, 1.10).is_empty());
        // a malformed previous document compares as empty, not a panic
        let junk = Json::parse(r#"{"schema":"x"}"#).unwrap();
        assert!(metric_regressions(&junk, &bad, &guards, 1.10).is_empty());
        // opting wall time in via its own guard flags the slowdown
        let slow = doc(100.0, 5000.0, 99.0);
        let regs = metric_regressions(&prev, &slow, &["wall_s"], 5.0);
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].path, "fig6.wall_s");
    }

    #[test]
    fn absolute_limits_gate_without_a_baseline() {
        let doc = Json::parse(
            r#"{"suites":{"micro":{"metrics":{"trace.overhead_x":1.02}},
                "fig6":{"metrics":{"other":3.0}}}}"#,
        )
        .unwrap();
        let ok = vec![("trace.overhead_x".to_string(), 1.05)];
        assert!(absolute_violations(&doc, &ok).is_empty());
        // over the limit → named violation
        let tight = vec![("trace.overhead_x".to_string(), 1.01)];
        let v = absolute_violations(&doc, &tight);
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("micro.trace.overhead_x"), "{v:?}");
        // a metric nobody recorded is a violation, not a silent pass
        let missing = vec![("trace.ghost".to_string(), 1.0)];
        let v = absolute_violations(&doc, &missing);
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("missing"), "{v:?}");
        // empty seed document: everything is missing
        let seed = Json::parse(r#"{"suites":{}}"#).unwrap();
        assert_eq!(absolute_violations(&seed, &ok).len(), 1);
    }

    #[test]
    fn empty_trajectory_is_detected_in_every_seed_shape() {
        // the committed seed: schema header, no suites recorded yet
        let seed = Json::parse(r#"{"schema":"esnmf-bench-smoke-v1","suites":{}}"#).unwrap();
        assert!(trajectory_is_empty(&seed));
        // degenerate shapes an old or hand-edited file might carry
        assert!(trajectory_is_empty(&Json::parse("{}").unwrap()));
        assert!(trajectory_is_empty(&Json::parse(r#"{"suites":3}"#).unwrap()));
        // one recorded suite — even metric-less — is a real baseline
        let recorded = Json::parse(r#"{"suites":{"micro":{"metrics":{}}}}"#).unwrap();
        assert!(!trajectory_is_empty(&recorded));
    }

    #[test]
    fn markdown_compare_reports_ratios_and_new_metrics() {
        let before_text = r#"{"suites":{"micro":{"metrics":{"wall_s_spmm":2.0,"other":7.0}}}}"#;
        let after_text =
            r#"{"suites":{"micro":{"metrics":{"wall_s_spmm":1.0,"wall_s_gram":0.5,"other":9.0}}}}"#;
        let before = Json::parse(before_text).unwrap();
        let after = Json::parse(after_text).unwrap();
        let md = markdown_compare(&before, &after, &["wall_s"]);
        let spmm_row = "| micro.wall_s_spmm | 2.000000 | 1.000000 | 0.500 |";
        let gram_row = "| micro.wall_s_gram | (new) | 0.500000 | n/a |";
        assert!(md.contains(spmm_row), "{md}");
        assert!(md.contains(gram_row), "{md}");
        // the unguarded metric stays out of the wall-clock report…
        assert!(!md.contains("other"), "{md}");
        // …and an empty guard list includes everything
        let all = markdown_compare(&before, &after, &[]);
        assert!(all.contains("| micro.other | 7.000000 | 9.000000 | 1.286 |"), "{all}");
    }

    #[test]
    fn combined_file_accumulates_suites() {
        let path = std::env::temp_dir().join("esnmf_bench_combined_test.json");
        let _ = std::fs::remove_file(&path);
        let mut a = BenchSuite::new("suite alpha");
        a.results.push(BenchResult {
            name: "x".into(),
            samples_s: vec![0.1],
        });
        a.merge_into_combined(&path).unwrap();
        let mut b = BenchSuite::new("suite beta");
        b.results.push(BenchResult {
            name: "y".into(),
            samples_s: vec![0.2],
        });
        b.merge_into_combined(&path).unwrap();
        // re-running a suite replaces its entry instead of duplicating
        a.results[0].samples_s = vec![0.3];
        a.merge_into_combined(&path).unwrap();

        let root = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(
            root.get("schema").and_then(Json::as_str),
            Some("esnmf-bench-smoke-v1")
        );
        let suites = root.get("suites").unwrap();
        let alpha = suites.get("suite_alpha").unwrap();
        let beta = suites.get("suite_beta").unwrap();
        assert_eq!(
            alpha.get("results").and_then(Json::as_arr).unwrap()[0]
                .get("median_s")
                .and_then(Json::as_f64),
            Some(0.3)
        );
        assert_eq!(beta.get("title").and_then(Json::as_str), Some("suite beta"));
        // a corrupt combined file self-heals instead of erroring
        std::fs::write(&path, "not json").unwrap();
        b.merge_into_combined(&path).unwrap();
        let root = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert!(root.get("suites").unwrap().get("suite_beta").is_some());
        a.results.clear();
        b.results.clear(); // keep the drop hook quiet
        std::fs::remove_file(&path).unwrap();
    }
}
