//! The solver-wide tracing plane: structured span timers, a bounded
//! in-memory event ring, an optional versioned-JSONL file sink, and the
//! always-on factorization progress gauges behind the admin `PROGRESS`
//! command.
//!
//! # Design constraints
//!
//! * **Telemetry, never an input.** Nothing the tracer records feeds
//!   back into the solver — factors are bit-identical with tracing on or
//!   off (`tests/integration_trace.rs` pins the digest).
//! * **Disabled-path cost ≈ zero.** When tracing is off, [`span`]
//!   compiles down to one relaxed counter increment plus a branch on an
//!   [`AtomicBool`]; every field/drop call no-ops on a `None`. The
//!   `trace.overhead_x` metric in `benches/micro_kernels.rs` pins the
//!   ratio (bench-check gates it ≤ 1.05x).
//! * **Bounded memory.** The ring keeps the newest [`RING_CAPACITY`]
//!   events; older ones are dropped (counted in `dropped`). The JSONL
//!   sink, when attached, sees every event.
//!
//! # Trace file schema (`esnmf-trace-v1`)
//!
//! Line 1 is a header object: `{"schema":"esnmf-trace-v1"}`. Every later
//! line is one event object with the reserved keys `seq` (monotone event
//! ordinal), `t_us` (µs since tracing was enabled, monotonic clock),
//! `span` (the span kind), `dur_us` (span duration; 0 for instantaneous
//! events) — all other keys are numeric telemetry fields. Readers MUST
//! ignore unknown keys (the forward-compatibility rule); writers may add
//! fields within v1 but never change the meaning of an existing key.

use super::json::Json;
use std::collections::BTreeMap;
use std::collections::VecDeque;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Instant;

/// Version tag written as the first line of every trace file.
pub const TRACE_SCHEMA: &str = "esnmf-trace-v1";

/// Newest events kept in memory for live snapshots (`TRACEDUMP` over the
/// admin listener, [`snapshot`]).
pub const RING_CAPACITY: usize = 8192;

/// The branch every span start takes. Relaxed everywhere: the tracer
/// tolerates a few events from the enabling/disabling instant landing on
/// either side.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Spans entered since process start, counted even while disabled — the
/// "relaxed counter" half of the disabled-path contract, and a cheap
/// sanity signal ("did the instrumentation run at all?").
static SPANS_ENTERED: AtomicU64 = AtomicU64::new(0);

/// One recorded span or instantaneous event.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub seq: u64,
    /// µs since tracing was enabled (monotonic clock).
    pub t_us: u64,
    /// Span kind — see the taxonomy in rust/README.md §Observability.
    pub span: &'static str,
    /// Wall duration in µs; 0 for instantaneous events.
    pub dur_us: u64,
    /// Numeric telemetry (nnz counts, tau, residuals, worker ordinals …).
    pub fields: Vec<(&'static str, f64)>,
}

impl TraceEvent {
    /// The event as one compact JSON object (one JSONL line).
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("seq".to_string(), Json::Num(self.seq as f64));
        obj.insert("t_us".to_string(), Json::Num(self.t_us as f64));
        obj.insert("span".to_string(), Json::Str(self.span.to_string()));
        obj.insert("dur_us".to_string(), Json::Num(self.dur_us as f64));
        for (k, v) in &self.fields {
            obj.insert(k.to_string(), Json::Num(*v));
        }
        Json::Obj(obj)
    }
}

struct TracerState {
    /// Set when tracing was enabled; `t_us` is measured from here.
    origin: Instant,
    ring: VecDeque<TraceEvent>,
    /// Events evicted from the ring since enable (they still reached the
    /// sink, if one is attached).
    dropped: u64,
    seq: u64,
    sink: Option<BufWriter<File>>,
}

fn tracer() -> &'static Mutex<Option<TracerState>> {
    static TRACER: OnceLock<Mutex<Option<TracerState>>> = OnceLock::new();
    TRACER.get_or_init(|| Mutex::new(None))
}

fn lock_tracer() -> MutexGuard<'static, Option<TracerState>> {
    tracer().lock().unwrap_or_else(PoisonError::into_inner)
}

/// Is the tracing plane collecting events right now?
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Total spans entered since process start (counted even while
/// disabled — the relaxed counter of the overhead contract).
pub fn spans_entered() -> u64 {
    SPANS_ENTERED.load(Ordering::Relaxed)
}

/// Turn tracing on. With a path, events additionally stream to that file
/// as versioned JSONL (the header line is written immediately); without
/// one, only the in-memory ring collects. Re-enabling resets the ring
/// and the clock.
pub fn enable(path: Option<&Path>) -> std::io::Result<()> {
    let sink = match path {
        None => None,
        Some(p) => {
            let mut w = BufWriter::new(File::create(p)?);
            let mut header = BTreeMap::new();
            header.insert("schema".to_string(), Json::Str(TRACE_SCHEMA.to_string()));
            writeln!(w, "{}", Json::Obj(header))?;
            Some(w)
        }
    };
    let mut guard = lock_tracer();
    *guard = Some(TracerState {
        origin: Instant::now(),
        ring: VecDeque::with_capacity(RING_CAPACITY.min(1024)),
        dropped: 0,
        seq: 0,
        sink,
    });
    ENABLED.store(true, Ordering::Relaxed);
    Ok(())
}

/// Turn tracing off, flushing and closing the sink. The ring survives
/// (snapshots still work) until the next [`enable`].
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
    let mut guard = lock_tracer();
    if let Some(state) = guard.as_mut() {
        if let Some(sink) = state.sink.take() {
            drop_flush(sink);
        }
    }
}

fn drop_flush(mut sink: BufWriter<File>) {
    if let Err(e) = sink.flush() {
        crate::log_warn!("trace", "flushing trace sink: {e}");
    }
}

/// Clone of the current ring contents, oldest first.
pub fn snapshot() -> Vec<TraceEvent> {
    lock_tracer()
        .as_ref()
        .map(|s| s.ring.iter().cloned().collect())
        .unwrap_or_default()
}

/// Events evicted from the ring since tracing was enabled.
pub fn dropped() -> u64 {
    lock_tracer().as_ref().map(|s| s.dropped).unwrap_or(0)
}

/// The ring rendered as trace-file text (header line + one JSONL line
/// per event) — the body of the admin `TRACEDUMP` command, parseable by
/// the same reader as a trace file.
pub fn ring_jsonl() -> String {
    let mut out = format!("{{\"schema\":\"{TRACE_SCHEMA}\"}}\n");
    for e in snapshot() {
        out.push_str(&e.to_json().to_string());
        out.push('\n');
    }
    out
}

fn record(span: &'static str, started: Option<Instant>, fields: Vec<(&'static str, f64)>) {
    let mut guard = lock_tracer();
    let Some(state) = guard.as_mut() else { return };
    let now = Instant::now();
    let dur_us = started
        .map(|s| now.duration_since(s).as_micros().min(u64::MAX as u128) as u64)
        .unwrap_or(0);
    let t_us = started.unwrap_or(now).duration_since(state.origin).as_micros() as u64;
    let event = TraceEvent {
        seq: state.seq,
        t_us,
        span,
        dur_us,
        fields,
    };
    state.seq += 1;
    if let Some(sink) = state.sink.as_mut() {
        // a full disk must never kill a run: drop the sink, keep the ring
        if writeln!(sink, "{}", event.to_json()).is_err() {
            crate::log_warn!("trace", "trace sink write failed; disabling the file sink");
            state.sink = None;
        }
    }
    if state.ring.len() >= RING_CAPACITY {
        state.ring.pop_front();
        state.dropped += 1;
    }
    state.ring.push_back(event);
}

/// A live span timer. Created by [`span`]; records one event (with its
/// wall duration and accumulated fields) when dropped. When tracing is
/// disabled the struct is inert — every method is a no-op on `None`.
#[must_use = "a span records on drop; binding it to _ drops immediately"]
pub struct Span {
    active: Option<(Instant, &'static str, Vec<(&'static str, f64)>)>,
}

impl Span {
    /// Attach a numeric telemetry field (no-op while disabled).
    pub fn field(&mut self, name: &'static str, value: f64) {
        if let Some((_, _, fields)) = self.active.as_mut() {
            fields.push((name, value));
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some((start, kind, fields)) = self.active.take() {
            record(kind, Some(start), fields);
        }
    }
}

/// Open a span of the given kind. The hot-path entry point: one relaxed
/// counter increment plus the enabled branch when tracing is off.
#[inline]
pub fn span(kind: &'static str) -> Span {
    SPANS_ENTERED.fetch_add(1, Ordering::Relaxed);
    if !ENABLED.load(Ordering::Relaxed) {
        return Span { active: None };
    }
    Span {
        active: Some((Instant::now(), kind, Vec::new())),
    }
}

/// Record an instantaneous event (`dur_us` = 0) with the given fields.
#[inline]
pub fn event(kind: &'static str, fields: &[(&'static str, f64)]) {
    SPANS_ENTERED.fetch_add(1, Ordering::Relaxed);
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    record(kind, None, fields.to_vec());
}

// ---------------------------------------------------------------------------
// The always-on progress plane (admin PROGRESS).
// ---------------------------------------------------------------------------

/// Live factorization progress — a handful of relaxed atomics updated at
/// every iteration boundary regardless of whether tracing is enabled, so
/// the factorize admin listener's `PROGRESS` command answers without any
/// coupling into the solver loop's data. All observational.
pub mod progress {
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::OnceLock;
    use std::time::Instant;

    static RUNNING: AtomicBool = AtomicBool::new(false);
    static ITER: AtomicU64 = AtomicU64::new(0);
    static MAX_ITERS: AtomicU64 = AtomicU64::new(0);
    /// f64 bit patterns (NaN = "no sample yet")
    static RESIDUAL_BITS: AtomicU64 = AtomicU64::new(f64::NAN.to_bits());
    static OBJECTIVE_BITS: AtomicU64 = AtomicU64::new(f64::NAN.to_bits());
    /// µs since the process origin at which the current run began
    static STARTED_US: AtomicU64 = AtomicU64::new(0);

    fn origin() -> Instant {
        static ORIGIN: OnceLock<Instant> = OnceLock::new();
        *ORIGIN.get_or_init(Instant::now)
    }

    fn now_us() -> u64 {
        origin().elapsed().as_micros().min(u64::MAX as u128) as u64
    }

    /// Mark a run as started (called at the top of the solver loop).
    /// `start_iter` > 0 on resumed runs.
    pub fn begin(start_iter: usize, max_iters: usize) {
        ITER.store(start_iter as u64, Ordering::Relaxed);
        MAX_ITERS.store(max_iters as u64, Ordering::Relaxed);
        RESIDUAL_BITS.store(f64::NAN.to_bits(), Ordering::Relaxed);
        OBJECTIVE_BITS.store(f64::NAN.to_bits(), Ordering::Relaxed);
        STARTED_US.store(now_us(), Ordering::Relaxed);
        RUNNING.store(true, Ordering::Relaxed);
    }

    /// Publish one completed iteration.
    pub fn update(iterations: usize, residual: f64, objective: Option<f64>) {
        ITER.store(iterations as u64, Ordering::Relaxed);
        RESIDUAL_BITS.store(residual.to_bits(), Ordering::Relaxed);
        if let Some(o) = objective {
            OBJECTIVE_BITS.store(o.to_bits(), Ordering::Relaxed);
        }
    }

    /// Mark the run as finished (the last published state survives).
    pub fn finish() {
        RUNNING.store(false, Ordering::Relaxed);
    }

    /// The admin `PROGRESS` response line: iteration counter, newest
    /// residual/objective samples, elapsed wall time, and a linear ETA
    /// extrapolated from the completed-iteration rate.
    pub fn render() -> String {
        let iter = ITER.load(Ordering::Relaxed);
        let max = MAX_ITERS.load(Ordering::Relaxed);
        if max == 0 {
            return "OK idle".to_string();
        }
        let running = RUNNING.load(Ordering::Relaxed);
        let mut out = format!(
            "OK {} iteration={iter}/{max}",
            if running { "running" } else { "done" }
        );
        let residual = f64::from_bits(RESIDUAL_BITS.load(Ordering::Relaxed));
        if !residual.is_nan() {
            out.push_str(&format!(" residual={residual:.6e}"));
        }
        let objective = f64::from_bits(OBJECTIVE_BITS.load(Ordering::Relaxed));
        if !objective.is_nan() {
            out.push_str(&format!(" objective={objective:.6e}"));
        }
        let elapsed_s =
            now_us().saturating_sub(STARTED_US.load(Ordering::Relaxed)) as f64 / 1e6;
        out.push_str(&format!(" elapsed_s={elapsed_s:.3}"));
        if running && iter > 0 && max > iter {
            let eta_s = elapsed_s / iter as f64 * (max - iter) as f64;
            out.push_str(&format!(" eta_s={eta_s:.3}"));
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Trace report: JSONL (file or ring dump) → markdown breakdown.
// ---------------------------------------------------------------------------

/// Aggregate per-span-kind statistics of parsed trace events.
#[derive(Default)]
struct SpanAgg {
    count: u64,
    total_us: f64,
    max_us: f64,
}

/// Parse trace-file text (or a `TRACEDUMP` body) into event objects,
/// enforcing the v1 header and ignoring unknown keys per the
/// forward-compatibility rule. Trailing non-JSON lines (e.g. the admin
/// dump's `# EOF`) are ignored.
pub fn parse_trace(text: &str) -> Result<Vec<Json>, String> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header = lines.next().ok_or("empty trace")?;
    let h = Json::parse(header).map_err(|e| format!("trace header: {e}"))?;
    match h.get("schema").and_then(Json::as_str) {
        Some(s) if s.starts_with("esnmf-trace-") => {}
        Some(s) => return Err(format!("not an esnmf trace (schema {s:?})")),
        None => return Err("trace header has no schema key".to_string()),
    }
    let mut events = Vec::new();
    for (i, line) in lines.enumerate() {
        if line.starts_with('#') {
            continue; // admin-dump terminator / future comments
        }
        let e = Json::parse(line).map_err(|e| format!("trace line {}: {e}", i + 2))?;
        if e.get("span").and_then(Json::as_str).is_none() {
            return Err(format!("trace line {}: no span key", i + 2));
        }
        events.push(e);
    }
    Ok(events)
}

fn field(e: &Json, name: &str) -> Option<f64> {
    e.get(name).and_then(Json::as_f64)
}

/// Render the markdown per-phase time / convergence / sparsity breakdown
/// of `esnmf trace-report` from parsed trace events.
pub fn render_report(events: &[Json]) -> String {
    let mut by_kind: BTreeMap<String, SpanAgg> = BTreeMap::new();
    for e in events {
        let kind = e.get("span").and_then(Json::as_str).unwrap_or("?");
        let agg = by_kind.entry(kind.to_string()).or_default();
        agg.count += 1;
        let dur = field(e, "dur_us").unwrap_or(0.0);
        agg.total_us += dur;
        agg.max_us = agg.max_us.max(dur);
    }
    let mut out = String::from("# Trace report\n\n## Time by span kind\n\n");
    out.push_str("| span | count | total_ms | mean_ms | max_ms |\n");
    out.push_str("|---|---:|---:|---:|---:|\n");
    for (kind, a) in &by_kind {
        out.push_str(&format!(
            "| {kind} | {} | {:.3} | {:.3} | {:.3} |\n",
            a.count,
            a.total_us / 1e3,
            a.total_us / 1e3 / a.count as f64,
            a.max_us / 1e3
        ));
    }

    let mut iters: Vec<&Json> = events
        .iter()
        .filter(|e| e.get("span").and_then(Json::as_str) == Some("iteration"))
        .collect();
    iters.sort_by(|a, b| {
        field(a, "iter")
            .partial_cmp(&field(b, "iter"))
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    if !iters.is_empty() {
        out.push_str("\n## Convergence\n\n| iter | residual | objective | ms |\n|---:|---:|---:|---:|\n");
        for e in &iters {
            let fmt = |v: Option<f64>| v.map_or("-".to_string(), |x| format!("{x:.6e}"));
            out.push_str(&format!(
                "| {} | {} | {} | {:.3} |\n",
                field(e, "iter").unwrap_or(0.0),
                fmt(field(e, "residual")),
                fmt(field(e, "objective")),
                field(e, "dur_us").unwrap_or(0.0) / 1e3
            ));
        }
    }

    let selects: Vec<&Json> = events
        .iter()
        .filter(|e| e.get("span").and_then(Json::as_str) == Some("select_pass"))
        .collect();
    let emits: Vec<&Json> = events
        .iter()
        .filter(|e| e.get("span").and_then(Json::as_str) == Some("emit_pass"))
        .collect();
    if !selects.is_empty() || !emits.is_empty() {
        out.push_str("\n## Sparsity\n\n");
        if !selects.is_empty() {
            let cand: f64 = selects.iter().filter_map(|e| field(e, "cand_nnz")).sum();
            let taus: Vec<f64> = selects.iter().filter_map(|e| field(e, "tau")).collect();
            out.push_str(&format!(
                "- select passes: {} (candidate nnz total {}, mean tau {})\n",
                selects.len(),
                cand as u64,
                if taus.is_empty() {
                    "-".to_string()
                } else {
                    format!("{:.6e}", taus.iter().sum::<f64>() / taus.len() as f64)
                }
            ));
        }
        if !emits.is_empty() {
            let kept: f64 = emits.iter().filter_map(|e| field(e, "nnz")).sum();
            out.push_str(&format!(
                "- emit passes: {} (post-enforcement nnz total {})\n",
                emits.len(),
                kept as u64
            ));
        }
    }

    let workers: Vec<&Json> = events
        .iter()
        .filter(|e| e.get("span").and_then(Json::as_str) == Some("worker_summary"))
        .collect();
    if !workers.is_empty() {
        out.push_str(
            "\n## Workers\n\n| worker | requests | compute_ms | wait_ms | straggler_rounds | reassigned_spans |\n|---:|---:|---:|---:|---:|---:|\n",
        );
        for e in &workers {
            out.push_str(&format!(
                "| {} | {} | {:.3} | {:.3} | {} | {} |\n",
                field(e, "worker").unwrap_or(-1.0),
                field(e, "requests").unwrap_or(0.0),
                field(e, "compute_us").unwrap_or(0.0) / 1e3,
                field(e, "wait_us").unwrap_or(0.0) / 1e3,
                field(e, "straggler_rounds").unwrap_or(0.0),
                field(e, "reassigned_spans").unwrap_or(0.0),
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The tracer is process-global; tests that enable it serialize here.
    fn trace_lock() -> MutexGuard<'static, ()> {
        static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
        LOCK.get_or_init(|| Mutex::new(()))
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
    }

    #[test]
    fn disabled_spans_record_nothing_but_count() {
        let _guard = trace_lock();
        disable();
        let before = spans_entered();
        {
            let mut s = span("test.noop");
            s.field("x", 1.0);
        }
        event("test.noop_event", &[("y", 2.0)]);
        assert_eq!(spans_entered(), before + 2);
        assert!(!snapshot().iter().any(|e| e.span.starts_with("test.noop")));
    }

    #[test]
    fn ring_collects_spans_with_fields_and_stays_bounded() {
        let _guard = trace_lock();
        enable(None).unwrap();
        {
            let mut s = span("test.work");
            s.field("nnz", 42.0);
        }
        event("test.mark", &[("iter", 3.0)]);
        let events = snapshot();
        let work = events.iter().find(|e| e.span == "test.work").unwrap();
        assert_eq!(work.fields, vec![("nnz", 42.0)]);
        let mark = events.iter().find(|e| e.span == "test.mark").unwrap();
        assert_eq!(mark.dur_us, 0);
        assert!(mark.seq > work.seq, "seq is monotone");
        // overflow evicts oldest, never grows past capacity
        for _ in 0..RING_CAPACITY + 10 {
            event("test.flood", &[]);
        }
        assert_eq!(snapshot().len(), RING_CAPACITY);
        assert!(dropped() > 0);
        assert!(!snapshot().iter().any(|e| e.span == "test.work"));
        disable();
    }

    #[test]
    fn jsonl_round_trips_through_the_parser() {
        let _guard = trace_lock();
        enable(None).unwrap();
        {
            let mut s = span("iteration");
            s.field("iter", 1.0);
            s.field("residual", 0.25);
        }
        let text = ring_jsonl();
        let events = parse_trace(&text).unwrap();
        let it = events
            .iter()
            .find(|e| e.get("span").and_then(Json::as_str) == Some("iteration"))
            .unwrap();
        assert_eq!(it.get("iter").and_then(Json::as_f64), Some(1.0));
        assert_eq!(it.get("residual").and_then(Json::as_f64), Some(0.25));
        assert!(it.get("seq").and_then(Json::as_f64).is_some());
        assert!(it.get("t_us").and_then(Json::as_f64).is_some());
        disable();
    }

    #[test]
    fn file_sink_writes_versioned_jsonl() {
        let _guard = trace_lock();
        let path = std::env::temp_dir().join("esnmf_trace_sink_test.jsonl");
        let _ = std::fs::remove_file(&path);
        enable(Some(&path)).unwrap();
        event("test.file_event", &[("v", 7.0)]);
        disable();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with(&format!("{{\"schema\":\"{TRACE_SCHEMA}\"}}")));
        let events = parse_trace(&text).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].get("v").and_then(Json::as_f64), Some(7.0));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn parser_rejects_wrong_schema_and_ignores_unknown_fields() {
        assert!(parse_trace("").is_err());
        assert!(parse_trace("{\"schema\":\"other-v9\"}\n").is_err());
        assert!(parse_trace("{\"nope\":1}\n").is_err());
        // forward compatibility: unknown keys and future fields pass through
        let text = "{\"schema\":\"esnmf-trace-v1\",\"future_header_key\":1}\n\
                    {\"seq\":0,\"t_us\":1,\"span\":\"x\",\"dur_us\":2,\"new_field\":9}\n\
                    # EOF\n";
        let events = parse_trace(text).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].get("new_field").and_then(Json::as_f64), Some(9.0));
        // an event line without a span key is corrupt, not ignored
        assert!(parse_trace("{\"schema\":\"esnmf-trace-v1\"}\n{\"seq\":0}\n").is_err());
    }

    #[test]
    fn report_renders_time_convergence_and_sparsity_sections() {
        let text = "{\"schema\":\"esnmf-trace-v1\"}\n\
            {\"seq\":0,\"t_us\":0,\"span\":\"iteration\",\"dur_us\":2000,\"iter\":1,\"residual\":0.5,\"objective\":0.9}\n\
            {\"seq\":1,\"t_us\":2000,\"span\":\"iteration\",\"dur_us\":1000,\"iter\":2,\"residual\":0.25}\n\
            {\"seq\":2,\"t_us\":100,\"span\":\"select_pass\",\"dur_us\":300,\"cand_nnz\":120,\"tau\":0.125}\n\
            {\"seq\":3,\"t_us\":500,\"span\":\"emit_pass\",\"dur_us\":200,\"nnz\":60}\n\
            {\"seq\":4,\"t_us\":3000,\"span\":\"worker_summary\",\"dur_us\":0,\"worker\":0,\"requests\":4,\"compute_us\":900,\"wait_us\":50,\"straggler_rounds\":1,\"reassigned_spans\":0}\n";
        let events = parse_trace(text).unwrap();
        let md = render_report(&events);
        assert!(md.contains("| iteration | 2 | 3.000 | 1.500 | 2.000 |"), "{md}");
        assert!(md.contains("## Convergence"), "{md}");
        assert!(md.contains("| 1 | 5.000000e-1 | 9.000000e-1 | 2.000 |"), "{md}");
        assert!(md.contains("| 2 | 2.500000e-1 | - | 1.000 |"), "{md}");
        assert!(md.contains("## Sparsity"), "{md}");
        assert!(md.contains("candidate nnz total 120"), "{md}");
        assert!(md.contains("post-enforcement nnz total 60"), "{md}");
        assert!(md.contains("## Workers"), "{md}");
        assert!(md.contains("| 0 | 4 | 0.900 | 0.050 | 1 | 0 |"), "{md}");
    }

    #[test]
    fn progress_renders_iteration_residual_and_eta() {
        let _guard = trace_lock();
        progress::begin(0, 10);
        assert!(progress::render().starts_with("OK running iteration=0/10"));
        progress::update(4, 0.125, Some(0.5));
        let line = progress::render();
        assert!(line.contains("iteration=4/10"), "{line}");
        assert!(line.contains("residual=1.250000e-1"), "{line}");
        assert!(line.contains("objective=5.000000e-1"), "{line}");
        assert!(line.contains("elapsed_s="), "{line}");
        assert!(line.contains("eta_s="), "{line}");
        progress::finish();
        let line = progress::render();
        assert!(line.starts_with("OK done"), "{line}");
        assert!(!line.contains("eta_s="), "no ETA once finished: {line}");
    }
}
