//! Summary statistics used by the bench harness and experiment reports.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (0.0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Quantile by linear interpolation on the sorted copy; q in [0, 1].
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q), "quantile {q} out of range");
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// A running summary that avoids storing every sample.
#[derive(Clone, Debug, Default)]
pub struct Running {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Welford update.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((stddev(&xs) - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn quantiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(quantile(&[], 0.5), 0.0);
    }

    #[test]
    fn running_matches_batch() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert!((r.mean() - mean(&xs)).abs() < 1e-9);
        assert!((r.stddev() - stddev(&xs)).abs() < 1e-9);
        assert_eq!(r.min, min(&xs));
        assert_eq!(r.max, max(&xs));
    }

    #[test]
    #[should_panic]
    fn quantile_out_of_range() {
        quantile(&[1.0], 1.5);
    }
}
