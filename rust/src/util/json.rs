//! Minimal JSON: a value type, a recursive-descent parser, and a writer.
//!
//! Used for the artifact manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`) and for machine-readable experiment output.
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP (sufficient for our ASCII manifests).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for building result objects.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            self.pos += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        c => return Err(format!("bad escape '\\{}'", c as char)),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let start = self.pos;
                    let mut end = start + 1;
                    while end < self.bytes.len() && (self.bytes[end] & 0xC0) == 0x80 {
                        end += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| "invalid utf8")?,
                    );
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {text:?}: {e}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected , or ] found {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} found {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"als_iter_64x96x4","n":64,"inputs":[["a",[64,96],"f32"]]}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"version": 2, "programs": [{"name": "x", "file": "x.hlo.txt",
            "kind": "als_iter", "n": 64, "m": 96, "k": 4,
            "inputs": [["a", [64, 96], "f32"], ["t_u", [], "i32"]],
            "outputs": [["u_new", [64, 4], "f32"]]}]}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize(), Some(2));
        let progs = v.get("programs").unwrap().as_arr().unwrap();
        assert_eq!(progs[0].get("k").unwrap().as_usize(), Some(4));
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".into())
        );
    }
}
