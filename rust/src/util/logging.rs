//! Leveled stderr logging with a process-global verbosity switch.
//!
//! Deliberately tiny: the coordinator needs timestamped, leveled progress
//! lines, not a logging framework. Controlled by `--verbose`/`--quiet` on
//! the CLI or `ESNMF_LOG` (error|warn|info|debug|trace).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level_from_env() {
    if let Ok(v) = std::env::var("ESNMF_LOG") {
        if let Some(l) = Level::parse(&v) {
            set_level(l);
        }
    }
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= MAX_LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let now = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default();
    eprintln!(
        "[{:>10}.{:03} {} {}] {}",
        now.as_secs(),
        now.subsec_millis(),
        level.tag(),
        target,
        msg
    );
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }
}
