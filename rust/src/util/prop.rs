//! A miniature property-testing harness (proptest is unavailable offline).
//!
//! A property is a closure over a seeded [`Rng`]; [`check`] runs it for a
//! fixed number of deterministic cases, reporting the failing seed so the
//! case can be replayed with `check_one`. Generators are free functions on
//! `Rng` (see `util::rng`) plus the helpers here for common shapes.

use super::rng::Rng;

pub const DEFAULT_CASES: u32 = 64;

/// Run `prop` for `cases` deterministic seeds derived from `base_seed`.
/// Panics with the failing seed embedded so the case is reproducible.
pub fn check<F: FnMut(&mut Rng)>(name: &str, base_seed: u64, cases: u32, mut prop: F) {
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case} (replay seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single case by seed (for debugging failures).
pub fn check_one<F: FnOnce(&mut Rng)>(seed: u64, prop: F) {
    let mut rng = Rng::new(seed);
    prop(&mut rng);
}

/// Random dense nonnegative matrix entries (row-major), sparsity in [0,1].
pub fn gen_sparse_dense(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Vec<f32> {
    (0..rows * cols)
        .map(|_| {
            if rng.f64() < density {
                rng.abs_normal_f32() + 1e-4
            } else {
                0.0
            }
        })
        .collect()
}

/// A random (rows, cols) pair with both dims in [1, max_dim].
pub fn gen_dims(rng: &mut Rng, max_dim: usize) -> (usize, usize) {
    (rng.range(1, max_dim + 1), rng.range(1, max_dim + 1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("tautology", 1, 16, |rng| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            check("always-fails", 2, 4, |_rng| panic!("boom"));
        });
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("replay seed"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn gen_sparse_density_extremes() {
        let mut rng = Rng::new(3);
        assert!(gen_sparse_dense(&mut rng, 5, 5, 0.0).iter().all(|&x| x == 0.0));
        assert!(gen_sparse_dense(&mut rng, 5, 5, 1.0).iter().all(|&x| x > 0.0));
    }

    #[test]
    fn gen_dims_in_range() {
        let mut rng = Rng::new(4);
        for _ in 0..100 {
            let (r, c) = gen_dims(&mut rng, 7);
            assert!((1..=7).contains(&r) && (1..=7).contains(&c));
        }
    }
}
