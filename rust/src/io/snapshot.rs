//! The `.esnmf` model snapshot: one self-describing binary file holding a
//! factorization and everything needed to serve or continue it.
//!
//! # File layout (all integers little-endian)
//!
//! ```text
//! magic    6 bytes   b"ESNMF\0"
//! version  u16       SNAPSHOT_VERSION (readers refuse newer files)
//! length   u64       payload byte count
//! crc32    u32       CRC-32 (IEEE) of the payload
//! payload  length bytes
//! ```
//!
//! The payload is a flat sequence of sections: solver options (including,
//! from version 2, the training objective — version-1 files predate
//! selectable objectives and always load as Frobenius), corpus digest,
//! the `U` and `V` factors ([`Csr::write_bytes`] — value *bits*
//! round-trip, so a loaded model answers queries bit-identically),
//! vocabulary terms, optional document labels + label names, and the
//! convergence progress (iteration count, residual/error history, memory
//! peaks, accumulated wall time) that lets `--resume` reproduce an
//! uninterrupted run.
//!
//! Every load path is total: truncated files, bit flips (CRC), absurd
//! section sizes and structurally invalid factors all surface as a typed
//! [`SnapshotError`], never a panic or an unbounded allocation.

use super::wire::{self, Reader, WireError};
use crate::nmf::memory::MemoryStats;
use crate::nmf::{NmfOptions, ObjectiveKind, SparsityMode};
use crate::sparse::{Csr, TieMode};
use crate::text::TermDocMatrix;
use std::fmt;
use std::path::Path;

/// Current format version. Bump on any layout change.
///
/// History: v1 had no objective field (all v1 models are Frobenius);
/// v2 appends the training objective tag to the options section.
pub const SNAPSHOT_VERSION: u16 = 2;

/// Hard ceiling on a snapshot's rank. Serving precomputes a dense k×k
/// Gram inverse, so an absurd `k` in an otherwise well-formed file would
/// be an unbounded allocation at load time — exactly what the format
/// promises cannot happen. 2¹⁴ topics is far beyond any real model and
/// keeps the Gram under a gigabyte.
pub const MAX_SNAPSHOT_K: usize = 1 << 14;

const MAGIC: &[u8; 6] = b"ESNMF\0";

/// Everything that can go wrong reading or validating a snapshot.
#[derive(Debug)]
pub enum SnapshotError {
    Io(std::io::Error),
    /// Not an `.esnmf` file at all.
    BadMagic,
    /// Written by a newer esnmf than this reader.
    UnsupportedVersion(u16),
    /// File ends before the declared payload does.
    Truncated { expected: usize, have: usize },
    /// Payload bytes do not match the stored checksum (bit rot / flip).
    CrcMismatch { stored: u32, computed: u32 },
    /// Checksum passes but a section does not parse.
    Corrupt(String),
    /// The snapshot is valid but does not belong to this corpus/config
    /// (digest or shape refusal at a wiring layer).
    Mismatch(String),
}

impl fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SnapshotError::Io(e) => write!(f, "snapshot i/o: {e}"),
            SnapshotError::BadMagic => write!(f, "not an .esnmf snapshot (bad magic)"),
            SnapshotError::UnsupportedVersion(v) => write!(
                f,
                "snapshot version {v} is newer than this build (max {SNAPSHOT_VERSION})"
            ),
            SnapshotError::Truncated { expected, have } => {
                write!(f, "snapshot truncated: expected {expected} bytes, have {have}")
            }
            SnapshotError::CrcMismatch { stored, computed } => write!(
                f,
                "snapshot checksum mismatch (stored {stored:#010x}, computed {computed:#010x}) — file is corrupt"
            ),
            SnapshotError::Corrupt(msg) => write!(f, "snapshot corrupt: {msg}"),
            SnapshotError::Mismatch(msg) => write!(f, "snapshot mismatch: {msg}"),
        }
    }
}

impl std::error::Error for SnapshotError {}

impl From<std::io::Error> for SnapshotError {
    fn from(e: std::io::Error) -> Self {
        SnapshotError::Io(e)
    }
}

impl From<WireError> for SnapshotError {
    fn from(e: WireError) -> Self {
        match e {
            WireError::Truncated { expected, have } => {
                SnapshotError::Truncated { expected, have }
            }
            WireError::Corrupt(msg) => SnapshotError::Corrupt(msg),
        }
    }
}

/// Convergence state carried by a snapshot so `--resume` can reproduce an
/// uninterrupted run's [`crate::nmf::NmfResult`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Progress {
    /// completed ALS iterations
    pub iterations: usize,
    /// relative residual per completed iteration
    pub residuals: Vec<f64>,
    /// relative error per completed iteration (empty if untracked)
    pub errors: Vec<f64>,
    /// memory peaks observed so far
    pub memory: MemoryStats,
    /// training wall time accumulated before this snapshot was written
    pub elapsed_s: f64,
}

/// A persisted model: factors, vocabulary, labels, options, digest, and
/// resume state.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub options: NmfOptions,
    /// term/topic factor (terms × k)
    pub u: Csr,
    /// document/topic factor (docs × k)
    pub v: Csr,
    pub terms: Vec<String>,
    pub doc_labels: Option<Vec<u32>>,
    pub label_names: Vec<String>,
    /// [`corpus_digest`] of the term-document matrix the factors were
    /// trained on — load paths that continue training refuse on mismatch
    pub corpus_digest: u64,
    pub progress: Progress,
}

/// Order-sensitive FNV-1a digest over everything that defines the
/// training input: matrix shape, sparsity structure, value bits, and the
/// vocabulary strings. Two corpora digest equal iff ALS would walk the
/// same data.
pub fn corpus_digest(tdm: &TermDocMatrix) -> u64 {
    let mut h = Fnv::new();
    h.usize(tdm.a.rows);
    h.usize(tdm.a.cols);
    h.usize(tdm.a.nnz());
    for &p in &tdm.a.indptr {
        h.usize(p);
    }
    for &i in &tdm.a.indices {
        h.u32(i);
    }
    for &v in &tdm.a.values {
        h.u32(v.to_bits());
    }
    for t in &tdm.terms {
        h.bytes(t.as_bytes());
        h.u32(0xffff_ffff); // term separator
    }
    h.finish()
}

struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x100_0000_01b3);
        }
    }

    fn u32(&mut self, x: u32) {
        self.bytes(&x.to_le_bytes());
    }

    fn usize(&mut self, x: usize) {
        self.bytes(&(x as u64).to_le_bytes());
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

impl Snapshot {
    /// Assemble a snapshot of a completed (or checkpointed) factorization.
    pub fn new(
        options: NmfOptions,
        u: Csr,
        v: Csr,
        tdm: &TermDocMatrix,
        progress: Progress,
    ) -> Snapshot {
        Snapshot {
            options,
            u,
            v,
            terms: tdm.terms.clone(),
            doc_labels: tdm.doc_labels.clone(),
            label_names: tdm.label_names.clone(),
            corpus_digest: corpus_digest(tdm),
            progress,
        }
    }

    /// Serialize to the `.esnmf` wire form.
    pub fn to_bytes(&self) -> Vec<u8> {
        self.to_bytes_versioned(SNAPSHOT_VERSION)
    }

    /// [`Self::to_bytes`] at an explicit format version. Writers always
    /// emit [`SNAPSHOT_VERSION`]; the older layouts exist so the
    /// compatibility tests exercise real v1 bytes rather than
    /// hand-patched buffers.
    fn to_bytes_versioned(&self, version: u16) -> Vec<u8> {
        let mut payload = Vec::new();
        write_options(&mut payload, &self.options, version);
        payload.extend_from_slice(&self.corpus_digest.to_le_bytes());
        self.u.write_bytes(&mut payload);
        self.v.write_bytes(&mut payload);
        wire::write_strings(&mut payload, &self.terms);
        wire::write_opt_labels(&mut payload, &self.doc_labels);
        wire::write_strings(&mut payload, &self.label_names);
        let p = &self.progress;
        payload.extend_from_slice(&(p.iterations as u64).to_le_bytes());
        wire::write_f64s(&mut payload, &p.residuals);
        wire::write_f64s(&mut payload, &p.errors);
        for m in [
            p.memory.max_combined_nnz,
            p.memory.max_intermediate_nnz,
            p.memory.final_u_nnz,
            p.memory.final_v_nnz,
        ] {
            payload.extend_from_slice(&(m as u64).to_le_bytes());
        }
        payload.extend_from_slice(&p.elapsed_s.to_bits().to_le_bytes());

        let mut out = Vec::with_capacity(payload.len() + 20);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&version.to_le_bytes());
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        out.extend_from_slice(&crc32(&payload).to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }

    /// Parse the `.esnmf` wire form.
    pub fn from_bytes(bytes: &[u8]) -> Result<Snapshot, SnapshotError> {
        if bytes.len() < MAGIC.len() + 2 + 8 + 4 {
            return Err(SnapshotError::Truncated {
                expected: MAGIC.len() + 2 + 8 + 4,
                have: bytes.len(),
            });
        }
        if &bytes[..MAGIC.len()] != MAGIC {
            return Err(SnapshotError::BadMagic);
        }
        let version = u16::from_le_bytes(bytes[6..8].try_into().unwrap());
        if version == 0 || version > SNAPSHOT_VERSION {
            return Err(SnapshotError::UnsupportedVersion(version));
        }
        let len = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        let stored_crc = u32::from_le_bytes(bytes[16..20].try_into().unwrap());
        let have = bytes.len() - 20;
        if have < len {
            return Err(SnapshotError::Truncated {
                expected: len,
                have,
            });
        }
        if have > len {
            return Err(SnapshotError::Corrupt(format!(
                "{} trailing bytes after payload",
                have - len
            )));
        }
        let payload = &bytes[20..20 + len];
        let computed = crc32(payload);
        if computed != stored_crc {
            return Err(SnapshotError::CrcMismatch {
                stored: stored_crc,
                computed,
            });
        }

        let mut r = Reader::new(payload);
        let options = read_options(&mut r, version)?;
        let corpus_digest = r.u64()?;
        let u = Csr::read_bytes(r.bytes, &mut r.pos).map_err(SnapshotError::Corrupt)?;
        let v = Csr::read_bytes(r.bytes, &mut r.pos).map_err(SnapshotError::Corrupt)?;
        let terms = wire::read_strings(&mut r)?;
        let doc_labels = wire::read_opt_labels(&mut r)?;
        let label_names = wire::read_strings(&mut r)?;
        let iterations = r.u64()? as usize;
        let residuals = wire::read_f64s(&mut r)?;
        let errors = wire::read_f64s(&mut r)?;
        let memory = MemoryStats {
            max_combined_nnz: r.u64()? as usize,
            max_intermediate_nnz: r.u64()? as usize,
            final_u_nnz: r.u64()? as usize,
            final_v_nnz: r.u64()? as usize,
        };
        let elapsed_s = f64::from_bits(r.u64()?);
        if r.pos != r.bytes.len() {
            return Err(SnapshotError::Corrupt(format!(
                "{} unparsed payload bytes",
                r.bytes.len() - r.pos
            )));
        }

        let snap = Snapshot {
            options,
            u,
            v,
            terms,
            doc_labels,
            label_names,
            corpus_digest,
            progress: Progress {
                iterations,
                residuals,
                errors,
                memory,
                elapsed_s,
            },
        };
        snap.validate_shapes()?;
        Ok(snap)
    }

    /// Internal consistency: factor shapes agree with k, the vocabulary,
    /// and each other; labels (if present) cover every document.
    fn validate_shapes(&self) -> Result<(), SnapshotError> {
        let k = self.options.k;
        if k == 0 || k > MAX_SNAPSHOT_K {
            return Err(SnapshotError::Corrupt(format!(
                "rank k={k} outside 1..={MAX_SNAPSHOT_K}"
            )));
        }
        if k > self.u.rows.max(self.v.rows) {
            return Err(SnapshotError::Corrupt(format!(
                "rank k={k} exceeds both factor heights ({} terms, {} docs)",
                self.u.rows, self.v.rows
            )));
        }
        if self.u.cols != k || self.v.cols != k {
            return Err(SnapshotError::Corrupt(format!(
                "factor widths ({}, {}) disagree with k={k}",
                self.u.cols, self.v.cols
            )));
        }
        if self.u.rows != self.terms.len() {
            return Err(SnapshotError::Corrupt(format!(
                "U has {} rows but the vocabulary has {} terms",
                self.u.rows,
                self.terms.len()
            )));
        }
        if let Some(labels) = &self.doc_labels {
            if labels.len() != self.v.rows {
                return Err(SnapshotError::Corrupt(format!(
                    "{} doc labels for {} documents",
                    labels.len(),
                    self.v.rows
                )));
            }
            let n = self.label_names.len() as u32;
            if let Some(&bad) = labels.iter().find(|&&l| l >= n) {
                return Err(SnapshotError::Corrupt(format!(
                    "doc label id {bad} out of range ({n} label names)"
                )));
            }
        }
        Ok(())
    }

    /// Whether this snapshot's progress can seed `--resume`: the ALS
    /// driver records exactly one residual per completed iteration, so a
    /// snapshot whose history disagrees (e.g. one saved from a sequential
    /// run, which is servable but not ALS-resumable) is refused.
    pub fn check_resumable(&self) -> Result<(), SnapshotError> {
        if self.progress.residuals.len() != self.progress.iterations {
            return Err(SnapshotError::Mismatch(format!(
                "not an ALS checkpoint: {} residuals for {} iterations \
                 (snapshots from other solvers serve but cannot resume)",
                self.progress.residuals.len(),
                self.progress.iterations
            )));
        }
        Ok(())
    }

    /// Write atomically: serialize to `<path>.tmp`, then rename over
    /// `path`, so a crash mid-write never leaves a torn snapshot where a
    /// good one (e.g. the previous checkpoint) used to be.
    pub fn save(&self, path: &Path) -> Result<(), SnapshotError> {
        let bytes = self.to_bytes();
        let tmp = path.with_extension("esnmf.tmp");
        std::fs::write(&tmp, &bytes)?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Snapshot, SnapshotError> {
        let bytes = std::fs::read(path)?;
        Snapshot::from_bytes(&bytes)
    }

    /// [`Self::load`], also returning the CRC-32 of the whole file. The
    /// serving plane records this as provenance: an operator can match
    /// the `PROVENANCE` admin line against `crc32 <file>` of the
    /// artifact they meant to deploy. One read, one checksum pass — no
    /// second disk touch.
    pub fn load_with_crc(path: &Path) -> Result<(Snapshot, u32), SnapshotError> {
        let bytes = std::fs::read(path)?;
        let crc = crc32(&bytes);
        let snap = Snapshot::from_bytes(&bytes)?;
        Ok((snap, crc))
    }

    /// Refuse to continue training against `tdm` unless it is the exact
    /// corpus this snapshot was trained on.
    pub fn check_corpus(&self, tdm: &TermDocMatrix) -> Result<(), SnapshotError> {
        self.check_digest(corpus_digest(tdm), tdm.n_terms(), tdm.n_docs())
    }

    /// As [`Self::check_corpus`] against a precomputed digest — the
    /// out-of-core corpus store (`.estdm`) carries its digest in
    /// metadata, so resuming against a store never re-hashes the matrix.
    pub fn check_digest(
        &self,
        digest: u64,
        n_terms: usize,
        n_docs: usize,
    ) -> Result<(), SnapshotError> {
        if digest != self.corpus_digest {
            return Err(SnapshotError::Mismatch(format!(
                "corpus digest {digest:#018x} does not match the snapshot's {:#018x} \
                 ({n_terms} terms × {n_docs} docs vs {} × {}); use warm-start for a changed corpus",
                self.corpus_digest, self.u.rows, self.v.rows,
            )));
        }
        Ok(())
    }

    /// Refuse a rank mismatch (e.g. `serve --model snap --k 7` against a
    /// k=5 snapshot).
    pub fn check_k(&self, k: usize) -> Result<(), SnapshotError> {
        if self.options.k != k {
            return Err(SnapshotError::Mismatch(format!(
                "requested k={k} but the snapshot was trained with k={}",
                self.options.k
            )));
        }
        Ok(())
    }

    /// Refuse an objective mismatch (e.g. `--resume --objective kl`
    /// against a Frobenius snapshot): multiplicative KL updates and
    /// least-squares half-steps cannot continue each other's histories,
    /// and a served model must fold documents in under the objective it
    /// was trained with.
    pub fn check_objective(&self, objective: ObjectiveKind) -> Result<(), SnapshotError> {
        if self.options.objective != objective {
            return Err(SnapshotError::Mismatch(format!(
                "requested objective {} but the snapshot was trained with {}",
                objective.name(),
                self.options.objective.name()
            )));
        }
        Ok(())
    }

    /// The training-time `t_v` budget, if sparsity enforcement was on —
    /// the natural default fold-in budget for a served snapshot.
    pub fn t_v(&self) -> Option<usize> {
        match self.options.sparsity {
            SparsityMode::Global { t_v, .. } => t_v,
            SparsityMode::PerColumn { t_v_col, .. } => t_v_col,
            _ => None,
        }
    }
}

// --- payload section codecs -------------------------------------------------
// (the bounds-checked Reader and the shared string/f64/label codecs live
// in `io::wire`, shared with the `.estdm` corpus store)

fn write_opt_usize(out: &mut Vec<u8>, v: Option<usize>) {
    match v {
        None => out.push(0),
        Some(x) => {
            out.push(1);
            out.extend_from_slice(&(x as u64).to_le_bytes());
        }
    }
}

fn read_opt_usize(r: &mut Reader) -> Result<Option<usize>, SnapshotError> {
    match r.u8()? {
        0 => Ok(None),
        1 => Ok(Some(r.u64()? as usize)),
        other => Err(SnapshotError::Corrupt(format!("bad option flag {other}"))),
    }
}

fn write_opt_f32(out: &mut Vec<u8>, v: Option<f32>) {
    match v {
        None => out.push(0),
        Some(x) => {
            out.push(1);
            out.extend_from_slice(&x.to_bits().to_le_bytes());
        }
    }
}

fn read_opt_f32(r: &mut Reader) -> Result<Option<f32>, SnapshotError> {
    match r.u8()? {
        0 => Ok(None),
        1 => Ok(Some(f32::from_bits(r.u32()?))),
        other => Err(SnapshotError::Corrupt(format!("bad option flag {other}"))),
    }
}

fn write_options(out: &mut Vec<u8>, o: &NmfOptions, version: u16) {
    out.extend_from_slice(&(o.k as u64).to_le_bytes());
    out.extend_from_slice(&(o.max_iters as u64).to_le_bytes());
    out.extend_from_slice(&o.tol.to_bits().to_le_bytes());
    out.extend_from_slice(&o.seed.to_le_bytes());
    write_opt_usize(out, o.init_nnz);
    out.push(o.track_error as u8);
    out.push(match o.tie_mode {
        TieMode::KeepTies => 0,
        TieMode::Exact => 1,
    });
    match o.sparsity {
        SparsityMode::None => out.push(0),
        SparsityMode::Global { t_u, t_v } => {
            out.push(1);
            write_opt_usize(out, t_u);
            write_opt_usize(out, t_v);
        }
        SparsityMode::PerColumn { t_u_col, t_v_col } => {
            out.push(2);
            write_opt_usize(out, t_u_col);
            write_opt_usize(out, t_v_col);
        }
        SparsityMode::Threshold { tau_u, tau_v } => {
            out.push(3);
            write_opt_f32(out, tau_u);
            write_opt_f32(out, tau_v);
        }
    }
    if version >= 2 {
        out.push(o.objective.tag());
    }
}

fn read_options(r: &mut Reader, version: u16) -> Result<NmfOptions, SnapshotError> {
    let k = r.u64()? as usize;
    let max_iters = r.u64()? as usize;
    let tol = f64::from_bits(r.u64()?);
    let seed = r.u64()?;
    let init_nnz = read_opt_usize(r)?;
    let track_error = match r.u8()? {
        0 => false,
        1 => true,
        other => {
            return Err(SnapshotError::Corrupt(format!(
                "bad track_error flag {other}"
            )))
        }
    };
    let tie_mode = match r.u8()? {
        0 => TieMode::KeepTies,
        1 => TieMode::Exact,
        other => return Err(SnapshotError::Corrupt(format!("bad tie mode {other}"))),
    };
    let sparsity = match r.u8()? {
        0 => SparsityMode::None,
        1 => SparsityMode::Global {
            t_u: read_opt_usize(r)?,
            t_v: read_opt_usize(r)?,
        },
        2 => SparsityMode::PerColumn {
            t_u_col: read_opt_usize(r)?,
            t_v_col: read_opt_usize(r)?,
        },
        3 => SparsityMode::Threshold {
            tau_u: read_opt_f32(r)?,
            tau_v: read_opt_f32(r)?,
        },
        other => return Err(SnapshotError::Corrupt(format!("bad sparsity tag {other}"))),
    };
    let objective = if version >= 2 {
        let tag = r.u8()?;
        ObjectiveKind::from_tag(tag)
            .ok_or_else(|| SnapshotError::Corrupt(format!("bad objective tag {tag}")))?
    } else {
        // v1 predates selectable objectives; every v1 model is Frobenius
        ObjectiveKind::Frobenius
    };
    // threads and block_rows are machine-local speed/memory knobs with a
    // bit-identical determinism contract, so they are deliberately not
    // persisted: a loaded model uses this machine's defaults (threads =
    // all cores, block_rows = auto / ESNMF_BLOCK_ROWS)
    let mut opts = NmfOptions::new(k)
        .with_iters(max_iters)
        .with_tol(tol)
        .with_seed(seed)
        .with_sparsity(sparsity)
        .with_track_error(track_error)
        .with_objective(objective);
    opts.tie_mode = tie_mode;
    opts.init_nnz = init_nnz;
    Ok(opts)
}

/// CRC-32 (IEEE 802.3, reflected, init/xorout `0xffffffff`) — the common
/// `crc32` of zlib/PNG. Table built once.
pub fn crc32(bytes: &[u8]) -> u32 {
    use std::sync::OnceLock;
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xedb8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        t
    });
    let mut crc = 0xffff_ffffu32;
    for &b in bytes {
        crc = table[((crc ^ b as u32) & 0xff) as usize] ^ (crc >> 8);
    }
    crc ^ 0xffff_ffff
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::text::TdmBuilder;

    fn tiny_tdm() -> TermDocMatrix {
        let mut b = TdmBuilder::new();
        for _ in 0..4 {
            b.add_text("coffee crop quotas coffee brazil crop", Some("econ"));
            b.add_text("electrons atoms hydrogen electrons atoms", Some("sci"));
        }
        b.freeze()
    }

    fn sample() -> Snapshot {
        let tdm = tiny_tdm();
        let opts = NmfOptions::new(2)
            .with_iters(7)
            .with_seed(3)
            .with_sparsity(SparsityMode::both(20, 30))
            .with_tol(1e-6);
        let r = crate::nmf::factorize(&tdm, &opts);
        Snapshot::new(
            opts,
            r.u.clone(),
            r.v.clone(),
            &tdm,
            Progress {
                iterations: r.iterations,
                residuals: r.residuals.clone(),
                errors: r.errors.clone(),
                memory: r.memory,
                elapsed_s: r.elapsed_s,
            },
        )
    }

    fn assert_equal(a: &Snapshot, b: &Snapshot) {
        assert_eq!(a.u, b.u);
        assert_eq!(a.v, b.v);
        assert_eq!(a.terms, b.terms);
        assert_eq!(a.doc_labels, b.doc_labels);
        assert_eq!(a.label_names, b.label_names);
        assert_eq!(a.corpus_digest, b.corpus_digest);
        assert_eq!(a.progress, b.progress);
        assert_eq!(a.options.k, b.options.k);
        assert_eq!(a.options.max_iters, b.options.max_iters);
        assert_eq!(a.options.tol, b.options.tol);
        assert_eq!(a.options.seed, b.options.seed);
        assert_eq!(a.options.init_nnz, b.options.init_nnz);
        assert_eq!(a.options.track_error, b.options.track_error);
        assert_eq!(a.options.tie_mode, b.options.tie_mode);
        assert_eq!(a.options.sparsity, b.options.sparsity);
        assert_eq!(a.options.objective, b.options.objective);
    }

    /// Reassemble a well-formed `.esnmf` file around a (possibly
    /// modified) payload: fresh length and CRC, chosen header version.
    fn file_from_payload(payload: &[u8], version: u16) -> Vec<u8> {
        let mut out = Vec::with_capacity(payload.len() + 20);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&version.to_le_bytes());
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        out.extend_from_slice(&crc32(payload).to_le_bytes());
        out.extend_from_slice(payload);
        out
    }

    /// Payload offset of the v2 objective tag byte: the options section
    /// comes first in the payload and the tag is its final byte.
    fn objective_byte_offset(snap: &Snapshot) -> usize {
        let mut opts = Vec::new();
        write_options(&mut opts, &snap.options, 2);
        opts.len() - 1
    }

    #[test]
    fn byte_roundtrip_is_identity() {
        let snap = sample();
        let back = Snapshot::from_bytes(&snap.to_bytes()).unwrap();
        assert_equal(&snap, &back);
    }

    #[test]
    fn file_roundtrip_is_identity() {
        let snap = sample();
        let path = std::env::temp_dir().join("esnmf_snapshot_unit.esnmf");
        snap.save(&path).unwrap();
        let back = Snapshot::load(&path).unwrap();
        assert_equal(&snap, &back);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn every_sparsity_mode_roundtrips() {
        let modes = [
            SparsityMode::None,
            SparsityMode::u_only(5),
            SparsityMode::v_only(9),
            SparsityMode::PerColumn {
                t_u_col: Some(3),
                t_v_col: None,
            },
            SparsityMode::Threshold {
                tau_u: Some(0.25),
                tau_v: None,
            },
        ];
        for mode in modes {
            let mut snap = sample();
            snap.options = snap.options.with_sparsity(mode);
            let back = Snapshot::from_bytes(&snap.to_bytes()).unwrap();
            assert_eq!(back.options.sparsity, mode);
        }
    }

    #[test]
    fn machine_local_knobs_are_not_persisted() {
        // threads and block_rows are this-machine knobs (results are
        // bit-identical at any setting); a snapshot written with exotic
        // values must load with the local defaults
        let mut snap = sample();
        snap.options.threads = 3;
        snap.options.block_rows = 7;
        let back = Snapshot::from_bytes(&snap.to_bytes()).unwrap();
        assert_eq!(
            back.options.threads,
            crate::coordinator::pool::default_threads()
        );
        assert_eq!(back.options.block_rows, 0, "block_rows loads as auto");
    }

    #[test]
    fn bad_magic_is_typed() {
        let mut bytes = sample().to_bytes();
        bytes[0] = b'X';
        assert!(matches!(
            Snapshot::from_bytes(&bytes),
            Err(SnapshotError::BadMagic)
        ));
    }

    #[test]
    fn future_version_refused() {
        let mut bytes = sample().to_bytes();
        bytes[6..8].copy_from_slice(&(SNAPSHOT_VERSION + 1).to_le_bytes());
        assert!(matches!(
            Snapshot::from_bytes(&bytes),
            Err(SnapshotError::UnsupportedVersion(_))
        ));
    }

    #[test]
    fn truncation_is_typed_at_every_length() {
        let bytes = sample().to_bytes();
        for cut in [0, 5, 19, 20, bytes.len() / 2, bytes.len() - 1] {
            match Snapshot::from_bytes(&bytes[..cut]) {
                Err(SnapshotError::Truncated { .. }) => {}
                other => panic!("prefix of {cut} bytes: {other:?}"),
            }
        }
    }

    #[test]
    fn every_payload_bit_flip_is_caught_by_crc() {
        let bytes = sample().to_bytes();
        // flip one bit in a spread of payload positions
        let n = bytes.len();
        for pos in [20, 21, 20 + (n - 20) / 3, 20 + (n - 20) / 2, n - 1] {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x10;
            match Snapshot::from_bytes(&bad) {
                Err(SnapshotError::CrcMismatch { .. }) => {}
                other => panic!("flip at {pos}: {other:?}"),
            }
        }
    }

    #[test]
    fn trailing_garbage_is_corrupt() {
        let mut bytes = sample().to_bytes();
        bytes.push(0);
        assert!(matches!(
            Snapshot::from_bytes(&bytes),
            Err(SnapshotError::Corrupt(_))
        ));
    }

    #[test]
    fn digest_pins_the_corpus() {
        let tdm = tiny_tdm();
        let snap = sample();
        snap.check_corpus(&tdm).unwrap();
        let mut b = TdmBuilder::new();
        b.add_text("entirely different words here different words", None);
        b.add_text("entirely different other words again here", None);
        let other = b.freeze();
        match snap.check_corpus(&other) {
            Err(SnapshotError::Mismatch(msg)) => {
                assert!(msg.contains("digest"), "{msg}");
            }
            other => panic!("{other:?}"),
        }
        assert_ne!(corpus_digest(&tdm), corpus_digest(&other));
    }

    #[test]
    fn k_mismatch_refused() {
        let snap = sample();
        snap.check_k(2).unwrap();
        assert!(matches!(snap.check_k(7), Err(SnapshotError::Mismatch(_))));
    }

    #[test]
    fn objective_roundtrips_for_both_kinds() {
        for objective in [ObjectiveKind::Frobenius, ObjectiveKind::Kl] {
            let mut snap = sample();
            snap.options = snap.options.with_objective(objective);
            let back = Snapshot::from_bytes(&snap.to_bytes()).unwrap();
            assert_eq!(back.options.objective, objective);
        }
    }

    #[test]
    fn v1_snapshots_load_as_frobenius() {
        // a file written before objectives existed must keep loading,
        // and must mean Frobenius — not whatever the default happens to
        // be in some future build
        let snap = sample();
        let v1 = snap.to_bytes_versioned(1);
        let back = Snapshot::from_bytes(&v1).unwrap();
        assert_eq!(back.options.objective, ObjectiveKind::Frobenius);
        assert_equal(&snap, &back);
    }

    #[test]
    fn version_zero_is_refused() {
        let mut bytes = sample().to_bytes();
        bytes[6..8].copy_from_slice(&0u16.to_le_bytes());
        assert!(matches!(
            Snapshot::from_bytes(&bytes),
            Err(SnapshotError::UnsupportedVersion(0))
        ));
    }

    #[test]
    fn unknown_objective_tag_is_corrupt_not_a_default() {
        // CRC-valid v2 file whose objective byte is from the future:
        // refuse with a typed error naming the field — silently reading
        // it as Frobenius would serve a model under the wrong math
        let snap = sample();
        let off = objective_byte_offset(&snap);
        let mut payload = snap.to_bytes()[20..].to_vec();
        payload[off] = 0xee;
        match Snapshot::from_bytes(&file_from_payload(&payload, 2)) {
            Err(SnapshotError::Corrupt(msg)) => {
                assert!(msg.contains("objective"), "{msg}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn objective_byte_truncation_and_bit_flip_are_typed() {
        let snap = sample();
        let off = objective_byte_offset(&snap);
        let bytes = snap.to_bytes();
        // file cut exactly at the objective byte: Truncated, not a panic
        match Snapshot::from_bytes(&bytes[..20 + off]) {
            Err(SnapshotError::Truncated { .. }) => {}
            other => panic!("{other:?}"),
        }
        // a bit flip in the objective byte is caught by the checksum
        let mut bad = bytes.clone();
        bad[20 + off] ^= 0x01;
        assert!(matches!(
            Snapshot::from_bytes(&bad),
            Err(SnapshotError::CrcMismatch { .. })
        ));
    }

    #[test]
    fn objective_mismatch_refused() {
        let snap = sample();
        snap.check_objective(ObjectiveKind::Frobenius).unwrap();
        match snap.check_objective(ObjectiveKind::Kl) {
            Err(SnapshotError::Mismatch(msg)) => {
                assert!(msg.contains("objective"), "{msg}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn t_v_extraction() {
        let mut snap = sample();
        assert_eq!(snap.t_v(), Some(30));
        snap.options = snap.options.with_sparsity(SparsityMode::None);
        assert_eq!(snap.t_v(), None);
        snap.options = snap.options.with_sparsity(SparsityMode::PerColumn {
            t_u_col: None,
            t_v_col: Some(4),
        });
        assert_eq!(snap.t_v(), Some(4));
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // standard test vector: "123456789" → 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn shape_validation_catches_internal_disagreement() {
        let mut snap = sample();
        snap.terms.pop(); // vocabulary no longer matches U's rows
        assert!(matches!(
            Snapshot::from_bytes(&snap.to_bytes()),
            Err(SnapshotError::Corrupt(_))
        ));
    }

    #[test]
    fn absurd_rank_is_rejected_before_any_gram_allocation() {
        // a well-formed, CRC-correct file whose options claim a huge k
        // (and whose 0-row factors trivially satisfy the width checks)
        // must be refused at load — serving would otherwise allocate a
        // dense k×k Gram
        let mut snap = sample();
        let k = MAX_SNAPSHOT_K + 1;
        snap.options = NmfOptions::new(k);
        snap.u = Csr::zeros(0, k);
        snap.v = Csr::zeros(0, k);
        snap.terms.clear();
        snap.doc_labels = None;
        match Snapshot::from_bytes(&snap.to_bytes()) {
            Err(SnapshotError::Corrupt(msg)) => assert!(msg.contains("rank"), "{msg}"),
            other => panic!("{other:?}"),
        }
        // a rank exceeding both factor heights is equally meaningless
        let mut snap = sample();
        snap.options = NmfOptions::new(64);
        snap.u = Csr::zeros(3, 64);
        snap.v = Csr::zeros(5, 64);
        snap.terms = vec!["a".into(), "b".into(), "c".into()];
        snap.doc_labels = None;
        assert!(matches!(
            Snapshot::from_bytes(&snap.to_bytes()),
            Err(SnapshotError::Corrupt(_))
        ));
    }
}
