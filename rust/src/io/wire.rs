//! Shared little-endian wire codecs for the binary formats under `io/`
//! (`.esnmf` model snapshots, `.estdm` corpus stores).
//!
//! Both formats promise the same totality contract: truncated input,
//! absurd section sizes and malformed strings surface as a typed error,
//! never a panic or an unbounded allocation. The bounds-checked
//! [`Reader`] and the string/f64 section codecs live here so the two
//! formats cannot drift apart; each format converts [`WireError`] into
//! its own error enum at the boundary.

use std::fmt;

/// Low-level decode failure, mapped into `SnapshotError` / `StoreError`
/// by the format layers.
#[derive(Debug)]
pub(crate) enum WireError {
    /// Input ends before a read the layout requires.
    Truncated { expected: usize, have: usize },
    /// Input is long enough but the bytes do not parse.
    Corrupt(String),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Truncated { expected, have } => {
                write!(f, "truncated: expected {expected} bytes, have {have}")
            }
            WireError::Corrupt(msg) => write!(f, "corrupt: {msg}"),
        }
    }
}

/// Bounds-checked little-endian payload reader.
pub(crate) struct Reader<'a> {
    pub bytes: &'a [u8],
    pub pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        Reader { bytes, pos: 0 }
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.bytes.len())
            .ok_or(WireError::Truncated {
                expected: self.pos.saturating_add(n),
                have: self.bytes.len(),
            })?;
        let s = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// An element count for a section of `elem_size`-byte items, rejected
    /// up front when the remaining payload cannot possibly hold it (so a
    /// corrupt length cannot trigger a huge allocation).
    pub fn len(&mut self, what: &str, elem_size: usize) -> Result<usize, WireError> {
        let n = self.u64()? as usize;
        let need = n
            .checked_mul(elem_size)
            .ok_or_else(|| WireError::Corrupt(format!("absurd {what} count {n}")))?;
        if self.bytes.len() - self.pos < need {
            return Err(WireError::Corrupt(format!(
                "{what} section claims {need} bytes, {} remain",
                self.bytes.len() - self.pos
            )));
        }
        Ok(n)
    }
}

pub(crate) fn write_strings(out: &mut Vec<u8>, strings: &[String]) {
    out.extend_from_slice(&(strings.len() as u64).to_le_bytes());
    for s in strings {
        out.extend_from_slice(&(s.len() as u64).to_le_bytes());
        out.extend_from_slice(s.as_bytes());
    }
}

pub(crate) fn read_strings(r: &mut Reader) -> Result<Vec<String>, WireError> {
    // each string costs at least its 8-byte length prefix
    let n = r.len("string table", 8)?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let len = r.len("string", 1)?;
        let bytes = r.take(len)?;
        out.push(
            std::str::from_utf8(bytes)
                .map_err(|e| WireError::Corrupt(format!("bad UTF-8 string: {e}")))?
                .to_string(),
        );
    }
    Ok(out)
}

pub(crate) fn write_f64s(out: &mut Vec<u8>, xs: &[f64]) {
    out.extend_from_slice(&(xs.len() as u64).to_le_bytes());
    for &x in xs {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

pub(crate) fn read_f64s(r: &mut Reader) -> Result<Vec<f64>, WireError> {
    let n = r.len("f64 series", 8)?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(f64::from_bits(r.u64()?));
    }
    Ok(out)
}

/// Optional doc labels exactly as both formats store them: a presence
/// byte, then a u32 count + ids.
pub(crate) fn write_opt_labels(out: &mut Vec<u8>, labels: &Option<Vec<u32>>) {
    match labels {
        None => out.push(0),
        Some(labels) => {
            out.push(1);
            out.extend_from_slice(&(labels.len() as u64).to_le_bytes());
            for &l in labels {
                out.extend_from_slice(&l.to_le_bytes());
            }
        }
    }
}

pub(crate) fn read_opt_labels(r: &mut Reader) -> Result<Option<Vec<u32>>, WireError> {
    match r.u8()? {
        0 => Ok(None),
        1 => {
            let n = r.len("doc labels", 4)?;
            let mut labels = Vec::with_capacity(n);
            for _ in 0..n {
                labels.push(r.u32()?);
            }
            Ok(Some(labels))
        }
        other => Err(WireError::Corrupt(format!("bad doc-label flag {other}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reader_bounds_are_typed() {
        let mut r = Reader::new(&[1, 2, 3]);
        assert_eq!(r.u8().unwrap(), 1);
        assert!(matches!(r.u64(), Err(WireError::Truncated { .. })));
        // absurd section counts are rejected before allocation
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        let mut r = Reader::new(&bytes);
        assert!(matches!(r.len("things", 8), Err(WireError::Corrupt(_))));
    }

    #[test]
    fn strings_and_labels_roundtrip() {
        let strings = vec!["alpha".to_string(), "βγ".to_string(), String::new()];
        let labels = Some(vec![0u32, 7, 42]);
        let mut out = Vec::new();
        write_strings(&mut out, &strings);
        write_opt_labels(&mut out, &labels);
        write_opt_labels(&mut out, &None);
        write_f64s(&mut out, &[1.5, -0.0]);
        let mut r = Reader::new(&out);
        assert_eq!(read_strings(&mut r).unwrap(), strings);
        assert_eq!(read_opt_labels(&mut r).unwrap(), labels);
        assert_eq!(read_opt_labels(&mut r).unwrap(), None);
        let f = read_f64s(&mut r).unwrap();
        assert_eq!(f[0], 1.5);
        assert_eq!(f[1].to_bits(), (-0.0f64).to_bits());
        assert_eq!(r.pos, out.len());
    }

    #[test]
    fn bad_utf8_is_corrupt() {
        let mut out = Vec::new();
        out.extend_from_slice(&1u64.to_le_bytes());
        out.extend_from_slice(&2u64.to_le_bytes());
        out.extend_from_slice(&[0xff, 0xfe]);
        let mut r = Reader::new(&out);
        assert!(matches!(read_strings(&mut r), Err(WireError::Corrupt(_))));
    }
}
