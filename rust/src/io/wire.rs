//! The crate's shared wire layer: codecs, framing, and typed requests
//! for every protocol surface.
//!
//! Three things live here so the formats and planes cannot drift apart:
//!
//! * **Binary codecs** — the bounds-checked [`Reader`] and the
//!   string/f64/label section codecs shared by the `.esnmf` snapshot and
//!   `.estdm` store formats, and by the worker frames below.
//! * **Text-plane framing and parsing** — [`LineReader`] (timeout-
//!   surviving line framing, shared by the serve and admin listeners)
//!   plus the typed request enums [`ServeRequest`] / [`AdminRequest`]
//!   with one strict parser each. A parse failure IS the complete
//!   `ERR ...` response line, so every plane refuses malformed input
//!   with the same semantics.
//! * **Worker-plane frames** — the length-prefixed binary frames of the
//!   distributed factorization protocol ([`WorkerMsg`]): magic + tag +
//!   bounded length, payloads decoded through [`Reader`].
//!
//! Every decoder promises the same totality contract: truncated input,
//! absurd section sizes and malformed payloads surface as a typed error
//! ([`WireError`], or an `ERR` line on the text planes), never a panic,
//! a hang, or an unbounded allocation.

use crate::nmf::ObjectiveKind;
use crate::sparse::Csr;
use std::fmt;
use std::io::{ErrorKind, Read, Write};

/// Low-level decode failure, mapped into `SnapshotError` / `StoreError`
/// by the format layers and into
/// [`EsnmfError::Wire`](crate::EsnmfError::Wire) by the worker plane.
#[derive(Debug)]
pub enum WireError {
    /// Input ends before a read the layout requires.
    Truncated { expected: usize, have: usize },
    /// Input is long enough but the bytes do not parse.
    Corrupt(String),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Truncated { expected, have } => {
                write!(f, "truncated: expected {expected} bytes, have {have}")
            }
            WireError::Corrupt(msg) => write!(f, "corrupt: {msg}"),
        }
    }
}

/// Bounds-checked little-endian payload reader.
pub(crate) struct Reader<'a> {
    pub bytes: &'a [u8],
    pub pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        Reader { bytes, pos: 0 }
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.bytes.len())
            .ok_or(WireError::Truncated {
                expected: self.pos.saturating_add(n),
                have: self.bytes.len(),
            })?;
        let s = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// An element count for a section of `elem_size`-byte items, rejected
    /// up front when the remaining payload cannot possibly hold it (so a
    /// corrupt length cannot trigger a huge allocation).
    pub fn len(&mut self, what: &str, elem_size: usize) -> Result<usize, WireError> {
        let n = self.u64()? as usize;
        let need = n
            .checked_mul(elem_size)
            .ok_or_else(|| WireError::Corrupt(format!("absurd {what} count {n}")))?;
        if self.bytes.len() - self.pos < need {
            return Err(WireError::Corrupt(format!(
                "{what} section claims {need} bytes, {} remain",
                self.bytes.len() - self.pos
            )));
        }
        Ok(n)
    }
}

pub(crate) fn write_strings(out: &mut Vec<u8>, strings: &[String]) {
    out.extend_from_slice(&(strings.len() as u64).to_le_bytes());
    for s in strings {
        out.extend_from_slice(&(s.len() as u64).to_le_bytes());
        out.extend_from_slice(s.as_bytes());
    }
}

pub(crate) fn read_strings(r: &mut Reader) -> Result<Vec<String>, WireError> {
    // each string costs at least its 8-byte length prefix
    let n = r.len("string table", 8)?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let len = r.len("string", 1)?;
        let bytes = r.take(len)?;
        out.push(
            std::str::from_utf8(bytes)
                .map_err(|e| WireError::Corrupt(format!("bad UTF-8 string: {e}")))?
                .to_string(),
        );
    }
    Ok(out)
}

pub(crate) fn write_f64s(out: &mut Vec<u8>, xs: &[f64]) {
    out.extend_from_slice(&(xs.len() as u64).to_le_bytes());
    for &x in xs {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

pub(crate) fn read_f64s(r: &mut Reader) -> Result<Vec<f64>, WireError> {
    let n = r.len("f64 series", 8)?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(f64::from_bits(r.u64()?));
    }
    Ok(out)
}

/// Optional doc labels exactly as both formats store them: a presence
/// byte, then a u32 count + ids.
pub(crate) fn write_opt_labels(out: &mut Vec<u8>, labels: &Option<Vec<u32>>) {
    match labels {
        None => out.push(0),
        Some(labels) => {
            out.push(1);
            out.extend_from_slice(&(labels.len() as u64).to_le_bytes());
            for &l in labels {
                out.extend_from_slice(&l.to_le_bytes());
            }
        }
    }
}

pub(crate) fn read_opt_labels(r: &mut Reader) -> Result<Option<Vec<u32>>, WireError> {
    match r.u8()? {
        0 => Ok(None),
        1 => {
            let n = r.len("doc labels", 4)?;
            let mut labels = Vec::with_capacity(n);
            for _ in 0..n {
                labels.push(r.u32()?);
            }
            Ok(Some(labels))
        }
        other => Err(WireError::Corrupt(format!("bad doc-label flag {other}"))),
    }
}

// ---------------------------------------------------------------------------
// Text-plane framing (serve + admin listeners)
// ---------------------------------------------------------------------------

/// Defensive cap on one text-protocol request line.
pub(crate) const MAX_LINE_BYTES: usize = 1 << 20;

/// Largest `BATCH <n>` the serve plane accepts.
pub const MAX_BATCH: usize = 256;

/// Minimal buffered line reader that survives read timeouts: a partial
/// line stays buffered across `WouldBlock`/`TimedOut`, so a connection
/// loop can poll its stop flag between read attempts. (`BufReader` makes
/// no such guarantee for `read_line` under errors.) Shared by the serve
/// and admin listeners.
pub(crate) struct LineReader<R: Read> {
    inner: R,
    buf: Vec<u8>,
    start: usize,
}

impl<R: Read> LineReader<R> {
    pub(crate) fn new(inner: R) -> Self {
        LineReader {
            inner,
            buf: Vec::new(),
            start: 0,
        }
    }

    /// Next newline-terminated line without the terminator (a trailing
    /// `\r` is stripped). `Ok(None)` = clean EOF; timeouts bubble up as
    /// errors with any partial line preserved for the next call.
    pub(crate) fn read_line(&mut self) -> std::io::Result<Option<String>> {
        loop {
            if let Some(pos) = self.buf[self.start..].iter().position(|&b| b == b'\n') {
                let end = self.start + pos;
                let mut slice = &self.buf[self.start..end];
                if slice.last() == Some(&b'\r') {
                    slice = &slice[..slice.len() - 1];
                }
                let line = String::from_utf8_lossy(slice).into_owned();
                self.start = end + 1;
                if self.start >= self.buf.len() {
                    self.buf.clear();
                    self.start = 0;
                }
                return Ok(Some(line));
            }
            if self.start > 0 {
                self.buf.drain(..self.start);
                self.start = 0;
            }
            if self.buf.len() > MAX_LINE_BYTES {
                return Err(std::io::Error::new(
                    ErrorKind::InvalidData,
                    "request line too long",
                ));
            }
            let mut chunk = [0u8; 4096];
            match self.inner.read(&mut chunk) {
                Ok(0) => {
                    if self.buf.is_empty() {
                        return Ok(None);
                    }
                    // final unterminated line before EOF
                    let mut slice = &self.buf[..];
                    if slice.last() == Some(&b'\r') {
                        slice = &slice[..slice.len() - 1];
                    }
                    let line = String::from_utf8_lossy(slice).into_owned();
                    self.buf.clear();
                    return Ok(Some(line));
                }
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e) => return Err(e),
            }
        }
    }
}

pub(crate) fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

// ---------------------------------------------------------------------------
// Typed text-plane requests (one strict parser per plane)
// ---------------------------------------------------------------------------

/// One parsed serve-plane request. Borrowed from the request line —
/// parsing allocates only for collected argument lists.
#[derive(Clone, Debug, PartialEq)]
pub(crate) enum ServeRequest<'a> {
    Topics,
    TopTerms { topic: usize, n: usize },
    Classify { words: Vec<&'a str> },
    FoldIn { doc: Vec<(&'a str, f32)> },
    Docs { topic: usize, n: usize },
    Stats,
    Ping,
    Quit,
    Batch { n: usize },
}

/// Strictly parse `<topic> [n]`: malformed numerics, `n = 0`, trailing
/// garbage, and out-of-range topics all answer ERR (never a default).
fn parse_topic_n(
    parts: &mut std::str::SplitWhitespace,
    usage: &str,
    k: usize,
) -> Result<(usize, usize), String> {
    let topic = match parts.next() {
        None => return Err(format!("ERR usage: {usage}")),
        Some(tok) => match tok.parse::<usize>() {
            Ok(t) => t,
            Err(_) => return Err(format!("ERR bad topic {tok:?} (usage: {usage})")),
        },
    };
    let n = match parts.next() {
        None => 5,
        Some(tok) => match tok.parse::<usize>() {
            Ok(0) => return Err(format!("ERR n must be >= 1 (usage: {usage})")),
            Ok(n) => n,
            Err(_) => return Err(format!("ERR bad count {tok:?} (usage: {usage})")),
        },
    };
    if parts.next().is_some() {
        return Err(format!("ERR trailing arguments (usage: {usage})"));
    }
    if topic >= k {
        return Err(format!("ERR topic {topic} out of range (k={k})"));
    }
    Ok((topic, n))
}

/// Strictly parse the argument of `BATCH <n>` (shared by the serve
/// connection loop and [`ServeRequest::parse`]).
pub(crate) fn parse_batch_n(tok: Option<&str>, extra: Option<&str>) -> Result<usize, String> {
    if extra.is_some() {
        return Err(format!(
            "ERR trailing arguments (usage: BATCH <n>, 1..={MAX_BATCH})"
        ));
    }
    match tok.and_then(|s| s.parse::<usize>().ok()) {
        Some(n) if (1..=MAX_BATCH).contains(&n) => Ok(n),
        _ => Err(format!("ERR usage: BATCH <n> (1..={MAX_BATCH})")),
    }
}

impl<'a> ServeRequest<'a> {
    /// Parse one serve-plane line against model dimension `k`. `Err` is
    /// the complete `ERR ...` response line — every malformed request is
    /// a typed refusal with shared semantics, never a default.
    pub(crate) fn parse(line: &'a str, k: usize) -> Result<ServeRequest<'a>, String> {
        let mut parts = line.split_whitespace();
        let cmd = parts.next().unwrap_or("").to_ascii_uppercase();
        match cmd.as_str() {
            "TOPICS" => Ok(ServeRequest::Topics),
            "TOPTERMS" => {
                let (topic, n) = parse_topic_n(&mut parts, "TOPTERMS <topic> [n]", k)?;
                Ok(ServeRequest::TopTerms { topic, n })
            }
            "CLASSIFY" => {
                let words: Vec<&str> = parts.collect();
                if words.is_empty() {
                    return Err("ERR usage: CLASSIFY <word> ...".into());
                }
                Ok(ServeRequest::Classify { words })
            }
            "FOLDIN" => {
                const USAGE: &str = "ERR usage: FOLDIN <word:count> ...";
                let mut doc: Vec<(&str, f32)> = Vec::new();
                for tok in parts {
                    let Some((word, count)) = tok.rsplit_once(':') else {
                        return Err(format!("{USAGE} (bad pair {tok:?})"));
                    };
                    if word.is_empty() {
                        return Err(format!("{USAGE} (bad pair {tok:?})"));
                    }
                    match count.parse::<f32>() {
                        Ok(c) if c.is_finite() && c > 0.0 => doc.push((word, c)),
                        _ => return Err(format!("{USAGE} (bad count {count:?} in {tok:?})")),
                    }
                }
                if doc.is_empty() {
                    return Err(USAGE.into());
                }
                Ok(ServeRequest::FoldIn { doc })
            }
            "DOCS" => {
                let (topic, n) = parse_topic_n(&mut parts, "DOCS <topic> [n]", k)?;
                Ok(ServeRequest::Docs { topic, n })
            }
            "STATS" => Ok(ServeRequest::Stats),
            "PING" => Ok(ServeRequest::Ping),
            "QUIT" => Ok(ServeRequest::Quit),
            "BATCH" => {
                let n = parse_batch_n(parts.next(), parts.next())?;
                Ok(ServeRequest::Batch { n })
            }
            "" => Err("ERR empty command".into()),
            other => Err(format!("ERR unknown command {other:?}")),
        }
    }
}

/// One parsed admin-plane request.
#[derive(Clone, Debug, PartialEq)]
pub(crate) enum AdminRequest {
    Health,
    Ready,
    Metrics,
    Provenance,
    Reload { path: String },
    Ping,
    /// Live solver progress (iteration, residual/objective, ETA) — the
    /// factorize admin surface; serve has no run to report.
    Progress,
    /// The in-memory trace ring as versioned JSONL, terminated by
    /// `# EOF` — feeds `esnmf trace-report --admin-port`.
    TraceDump,
}

impl AdminRequest {
    /// Parse one admin-plane line; `Err` is the complete `ERR ...`
    /// response line, exactly as on the serve plane.
    pub(crate) fn parse(line: &str) -> Result<AdminRequest, String> {
        let mut parts = line.split_whitespace();
        let cmd = parts.next().unwrap_or("").to_ascii_uppercase();
        match cmd.as_str() {
            "HEALTH" => Ok(AdminRequest::Health),
            "READY" => Ok(AdminRequest::Ready),
            "METRICS" => Ok(AdminRequest::Metrics),
            "PROVENANCE" => Ok(AdminRequest::Provenance),
            "RELOAD" => match (parts.next(), parts.next()) {
                (Some(p), None) => Ok(AdminRequest::Reload {
                    path: p.to_string(),
                }),
                _ => Err("ERR usage: RELOAD <path.esnmf>".into()),
            },
            "PING" => Ok(AdminRequest::Ping),
            "PROGRESS" => Ok(AdminRequest::Progress),
            "TRACEDUMP" => Ok(AdminRequest::TraceDump),
            "" => Err("ERR empty command".into()),
            other => Err(format!("ERR unknown admin command {other:?}")),
        }
    }
}

// ---------------------------------------------------------------------------
// Worker-plane binary frames (distributed factorization)
// ---------------------------------------------------------------------------

/// Frame magic of the worker plane (`ESNW`).
pub(crate) const WORKER_MAGIC: [u8; 4] = *b"ESNW";

/// Protocol version exchanged in the `Hello`/`Welcome` handshake; a
/// worker and coordinator refuse to pair across versions.
///
/// History: v1 was Frobenius-only (`Hello` carried no objective and
/// `Compute` shipped a Gram inverse); v2 announces the objective in the
/// handshake and ships objective-specific auxiliary data plus an
/// optional previous factor in `Compute`; v3 appends a typed
/// [`WorkerSummary`] (compute wall time + items produced) to every
/// `Selected`/`Fragments` reply so the coordinator can aggregate
/// per-worker compute/wait/straggle telemetry. The handshake refusal
/// makes cross-version pairs impossible, so the appended section needs
/// no in-band presence flag.
pub(crate) const WORKER_PROTOCOL_VERSION: u16 = 3;

/// Defensive cap on one worker frame's payload. Fragment frames carry a
/// span's surviving nonzeros (u32 index + f32 value each), so a gigabyte
/// bounds spans far beyond anything the coordinator assigns.
pub(crate) const MAX_FRAME_BYTES: usize = 1 << 30;

/// One enforcement pass a worker runs over its assigned block span.
#[derive(Clone, Debug, PartialEq)]
pub(crate) enum PassReq {
    /// Pass 1 of global enforcement: fold every solved + projected
    /// candidate value of the span into one O(t) top-t selector.
    Select { t: u64 },
    /// Emission: filter the span's candidate values with the keep
    /// predicate `(keep_tag, tau)` (the wire form of the half-step's
    /// `Keep` enum; tags 0=All, 1=FiniteAtLeast, 2=AtLeast,
    /// 3=AboveOrTie) and return CSR fragments.
    Emit { keep_tag: u8, tau: f32 },
}

/// One self-contained half-step work assignment: everything a stateless
/// worker needs to compute blocks `span.0..span.1` of the global block
/// list `fixed_chunks(rows, block_rows)` — the fixed factor (bit-exact
/// CSR), the objective's precomputed auxiliary data (computed once by
/// the coordinator so every worker solves against identical bits), and
/// the pass to run.
#[derive(Clone, Debug, PartialEq)]
pub(crate) struct ComputeReq {
    /// `true`: update-U half-step (stream `A`'s rows); `false`:
    /// update-V half-step (stream `Aᵀ`'s rows).
    pub step_u: bool,
    /// the objective this half-step runs under (fixes the meaning of
    /// `aux` and whether `prev` must be present)
    pub objective: ObjectiveKind,
    pub k: u32,
    pub block_rows: u64,
    /// assigned block-index span `[lo, hi)` of the global block list
    pub span: (u64, u64),
    /// the fixed factor of this half-step
    pub factor: Csr,
    /// objective-specific per-half-step auxiliary data: the row-major
    /// (k × k) ridged Gram inverse for Frobenius, the k column sums of
    /// the fixed factor for KL
    pub aux: Vec<f32>,
    /// previous value of the factor being updated — required by KL
    /// (multiplicative updates rescale the previous rows), absent for
    /// Frobenius (least squares re-solves each row from scratch)
    pub prev: Option<Csr>,
    pub pass: PassReq,
}

/// One CSR fragment a worker emits for one block (the wire form of the
/// half-step's per-block emission).
#[derive(Clone, Debug, PartialEq)]
pub(crate) struct WireEmit {
    /// surviving nonzeros per output row of the block
    pub row_nnz: Vec<u32>,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
    /// candidate scratch the block materialized (memory telemetry)
    pub scratch_len: u64,
}

/// Span summary a worker attaches to every compute reply (v3):
/// observational telemetry the coordinator folds into its per-worker
/// counters and trace events. Never an input to the factorization.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub(crate) struct WorkerSummary {
    /// wall time the worker spent inside `compute()` for this request
    pub compute_us: u64,
    /// items produced: candidate values offered (select pass) or
    /// surviving nonzeros emitted (emit pass)
    pub items: u64,
}

/// Every frame of the worker plane. Directions: workers send `Hello`,
/// `Selected`, `Fragments`, `Refuse` and `Pong`; coordinators send
/// `Welcome`, `Compute`, `Ping`, `Shutdown` and `Refuse`.
#[derive(Clone, Debug, PartialEq)]
pub(crate) enum WorkerMsg {
    /// Worker handshake: protocol version, the digest and shape of the
    /// `.estdm` it opened, and the objective it was launched under — so
    /// a coordinator refuses a worker serving different data or running
    /// different per-block math before any work is assigned.
    Hello {
        version: u16,
        digest: u64,
        n_terms: u64,
        n_docs: u64,
        objective: ObjectiveKind,
    },
    /// Coordinator handshake acknowledgement.
    Welcome { version: u16 },
    Compute(ComputeReq),
    /// Select-pass reply: per-block candidate scratch sizes (block order
    /// within the span) and the worker's merged top-t selector state.
    Selected {
        scratch_lens: Vec<u64>,
        positives: u64,
        heap: Vec<f32>,
        summary: WorkerSummary,
    },
    /// Emit-pass reply: one fragment per block, span order.
    Fragments {
        emits: Vec<WireEmit>,
        summary: WorkerSummary,
    },
    /// Typed refusal — the peer violated the protocol or the request
    /// could not be served (digest mismatch, bad span, store fault).
    Refuse { message: String },
    Ping,
    Pong,
    /// Coordinator → worker: the run is over, exit cleanly.
    Shutdown,
}

impl WorkerMsg {
    /// The span summary attached to compute replies (v3), if this frame
    /// carries one.
    pub(crate) fn summary(&self) -> Option<WorkerSummary> {
        match self {
            WorkerMsg::Selected { summary, .. } | WorkerMsg::Fragments { summary, .. } => {
                Some(*summary)
            }
            _ => None,
        }
    }

    fn tag(&self) -> u8 {
        match self {
            WorkerMsg::Hello { .. } => 1,
            WorkerMsg::Welcome { .. } => 2,
            WorkerMsg::Compute(_) => 3,
            WorkerMsg::Selected { .. } => 4,
            WorkerMsg::Fragments { .. } => 5,
            WorkerMsg::Refuse { .. } => 6,
            WorkerMsg::Ping => 7,
            WorkerMsg::Pong => 8,
            WorkerMsg::Shutdown => 9,
        }
    }
}

fn write_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.extend_from_slice(&(xs.len() as u64).to_le_bytes());
    for &x in xs {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

fn read_f32s(r: &mut Reader) -> Result<Vec<f32>, WireError> {
    let n = r.len("f32 series", 4)?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(f32::from_bits(r.u32()?));
    }
    Ok(out)
}

fn write_u32s(out: &mut Vec<u8>, xs: &[u32]) {
    out.extend_from_slice(&(xs.len() as u64).to_le_bytes());
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn read_u32s(r: &mut Reader) -> Result<Vec<u32>, WireError> {
    let n = r.len("u32 series", 4)?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(r.u32()?);
    }
    Ok(out)
}

fn write_u64s(out: &mut Vec<u8>, xs: &[u64]) {
    out.extend_from_slice(&(xs.len() as u64).to_le_bytes());
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn read_u64s(r: &mut Reader) -> Result<Vec<u64>, WireError> {
    let n = r.len("u64 series", 8)?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(r.u64()?);
    }
    Ok(out)
}

fn write_summary(out: &mut Vec<u8>, s: &WorkerSummary) {
    out.extend_from_slice(&s.compute_us.to_le_bytes());
    out.extend_from_slice(&s.items.to_le_bytes());
}

fn read_summary(r: &mut Reader) -> Result<WorkerSummary, WireError> {
    Ok(WorkerSummary {
        compute_us: r.u64()?,
        items: r.u64()?,
    })
}

/// Decode an objective tag byte; an unknown tag (a future objective) is
/// a typed refusal, never a silent default.
fn read_objective(r: &mut Reader) -> Result<ObjectiveKind, WireError> {
    let tag = r.u8()?;
    ObjectiveKind::from_tag(tag)
        .ok_or_else(|| WireError::Corrupt(format!("bad objective tag {tag}")))
}

/// Serialize one message's payload (frame header excluded).
fn encode_payload(msg: &WorkerMsg) -> Vec<u8> {
    let mut out = Vec::new();
    match msg {
        WorkerMsg::Hello {
            version,
            digest,
            n_terms,
            n_docs,
            objective,
        } => {
            out.extend_from_slice(&version.to_le_bytes());
            out.extend_from_slice(&digest.to_le_bytes());
            out.extend_from_slice(&n_terms.to_le_bytes());
            out.extend_from_slice(&n_docs.to_le_bytes());
            out.push(objective.tag());
        }
        WorkerMsg::Welcome { version } => {
            out.extend_from_slice(&version.to_le_bytes());
        }
        WorkerMsg::Compute(req) => {
            out.push(u8::from(req.step_u));
            out.push(req.objective.tag());
            out.extend_from_slice(&req.k.to_le_bytes());
            out.extend_from_slice(&req.block_rows.to_le_bytes());
            out.extend_from_slice(&req.span.0.to_le_bytes());
            out.extend_from_slice(&req.span.1.to_le_bytes());
            match &req.pass {
                PassReq::Select { t } => {
                    out.push(0);
                    out.extend_from_slice(&t.to_le_bytes());
                }
                PassReq::Emit { keep_tag, tau } => {
                    out.push(1);
                    out.push(*keep_tag);
                    out.extend_from_slice(&tau.to_bits().to_le_bytes());
                }
            }
            write_f32s(&mut out, &req.aux);
            req.factor.write_bytes(&mut out);
            match &req.prev {
                None => out.push(0),
                Some(prev) => {
                    out.push(1);
                    prev.write_bytes(&mut out);
                }
            }
        }
        WorkerMsg::Selected {
            scratch_lens,
            positives,
            heap,
            summary,
        } => {
            write_u64s(&mut out, scratch_lens);
            out.extend_from_slice(&positives.to_le_bytes());
            write_f32s(&mut out, heap);
            write_summary(&mut out, summary);
        }
        WorkerMsg::Fragments { emits, summary } => {
            out.extend_from_slice(&(emits.len() as u64).to_le_bytes());
            for e in emits {
                write_u32s(&mut out, &e.row_nnz);
                write_u32s(&mut out, &e.indices);
                write_f32s(&mut out, &e.values);
                out.extend_from_slice(&e.scratch_len.to_le_bytes());
            }
            write_summary(&mut out, summary);
        }
        WorkerMsg::Refuse { message } => {
            write_strings(&mut out, std::slice::from_ref(message));
        }
        WorkerMsg::Ping | WorkerMsg::Pong | WorkerMsg::Shutdown => {}
    }
    out
}

/// Parse one message's payload for `tag`. Trailing bytes are corrupt —
/// a frame means exactly one message.
fn decode_payload(tag: u8, payload: &[u8]) -> Result<WorkerMsg, WireError> {
    let mut r = Reader::new(payload);
    let msg = match tag {
        1 => WorkerMsg::Hello {
            version: u16::from_le_bytes(r.take(2)?.try_into().unwrap()),
            digest: r.u64()?,
            n_terms: r.u64()?,
            n_docs: r.u64()?,
            objective: read_objective(&mut r)?,
        },
        2 => WorkerMsg::Welcome {
            version: u16::from_le_bytes(r.take(2)?.try_into().unwrap()),
        },
        3 => {
            let step_u = match r.u8()? {
                0 => false,
                1 => true,
                other => {
                    return Err(WireError::Corrupt(format!("bad step flag {other}")));
                }
            };
            let objective = read_objective(&mut r)?;
            let k = r.u32()?;
            let block_rows = r.u64()?;
            let span = (r.u64()?, r.u64()?);
            let pass = match r.u8()? {
                0 => PassReq::Select { t: r.u64()? },
                1 => {
                    let keep_tag = r.u8()?;
                    if keep_tag > 3 {
                        return Err(WireError::Corrupt(format!("bad keep tag {keep_tag}")));
                    }
                    PassReq::Emit {
                        keep_tag,
                        tau: f32::from_bits(r.u32()?),
                    }
                }
                other => {
                    return Err(WireError::Corrupt(format!("bad pass tag {other}")));
                }
            };
            let aux = read_f32s(&mut r)?;
            let factor = Csr::read_bytes(r.bytes, &mut r.pos)
                .map_err(|e| WireError::Corrupt(format!("factor: {e}")))?;
            let prev = match r.u8()? {
                0 => None,
                1 => Some(
                    Csr::read_bytes(r.bytes, &mut r.pos)
                        .map_err(|e| WireError::Corrupt(format!("prev factor: {e}")))?,
                ),
                other => {
                    return Err(WireError::Corrupt(format!("bad prev-factor flag {other}")));
                }
            };
            WorkerMsg::Compute(ComputeReq {
                step_u,
                objective,
                k,
                block_rows,
                span,
                factor,
                aux,
                prev,
                pass,
            })
        }
        4 => WorkerMsg::Selected {
            scratch_lens: read_u64s(&mut r)?,
            positives: r.u64()?,
            heap: read_f32s(&mut r)?,
            summary: read_summary(&mut r)?,
        },
        5 => {
            // each fragment costs at least its three 8-byte length
            // prefixes plus the scratch-len field
            let n = r.len("fragment list", 32)?;
            let mut emits = Vec::with_capacity(n);
            for _ in 0..n {
                emits.push(WireEmit {
                    row_nnz: read_u32s(&mut r)?,
                    indices: read_u32s(&mut r)?,
                    values: read_f32s(&mut r)?,
                    scratch_len: r.u64()?,
                });
            }
            WorkerMsg::Fragments {
                emits,
                summary: read_summary(&mut r)?,
            }
        }
        6 => {
            let mut strings = read_strings(&mut r)?;
            if strings.len() != 1 {
                return Err(WireError::Corrupt(format!(
                    "refusal carries {} strings, wanted 1",
                    strings.len()
                )));
            }
            WorkerMsg::Refuse {
                message: strings.pop().unwrap(),
            }
        }
        7 => WorkerMsg::Ping,
        8 => WorkerMsg::Pong,
        9 => WorkerMsg::Shutdown,
        other => {
            return Err(WireError::Corrupt(format!("unknown frame tag {other}")));
        }
    };
    if r.pos != payload.len() {
        return Err(WireError::Corrupt(format!(
            "{} trailing bytes after frame payload",
            payload.len() - r.pos
        )));
    }
    Ok(msg)
}

/// Write one framed message: magic, tag, payload length, payload.
pub(crate) fn write_msg<W: Write>(w: &mut W, msg: &WorkerMsg) -> std::io::Result<()> {
    let payload = encode_payload(msg);
    debug_assert!(payload.len() <= MAX_FRAME_BYTES);
    let mut frame = Vec::with_capacity(9 + payload.len());
    frame.extend_from_slice(&WORKER_MAGIC);
    frame.push(msg.tag());
    frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    frame.extend_from_slice(&payload);
    w.write_all(&frame)?;
    w.flush()
}

/// Read one framed message. I/O failures (including read timeouts — the
/// coordinator's straggler detection) surface as
/// [`EsnmfError::Io`](crate::EsnmfError::Io); malformed frames as
/// [`EsnmfError::Wire`](crate::EsnmfError::Wire). Never hangs past the
/// stream's own timeout, never allocates past [`MAX_FRAME_BYTES`].
pub(crate) fn read_msg<R: Read>(r: &mut R) -> Result<WorkerMsg, crate::EsnmfError> {
    let mut header = [0u8; 9];
    r.read_exact(&mut header)?;
    if header[0..4] != WORKER_MAGIC {
        return Err(WireError::Corrupt(format!(
            "bad frame magic {:02x?} (not a worker-plane peer)",
            &header[0..4]
        ))
        .into());
    }
    let tag = header[4];
    let len = u32::from_le_bytes(header[5..9].try_into().unwrap()) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(WireError::Corrupt(format!(
            "frame claims {len} payload bytes (cap {MAX_FRAME_BYTES})"
        ))
        .into());
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(decode_payload(tag, &payload)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reader_bounds_are_typed() {
        let mut r = Reader::new(&[1, 2, 3]);
        assert_eq!(r.u8().unwrap(), 1);
        assert!(matches!(r.u64(), Err(WireError::Truncated { .. })));
        // absurd section counts are rejected before allocation
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        let mut r = Reader::new(&bytes);
        assert!(matches!(r.len("things", 8), Err(WireError::Corrupt(_))));
    }

    #[test]
    fn strings_and_labels_roundtrip() {
        let strings = vec!["alpha".to_string(), "βγ".to_string(), String::new()];
        let labels = Some(vec![0u32, 7, 42]);
        let mut out = Vec::new();
        write_strings(&mut out, &strings);
        write_opt_labels(&mut out, &labels);
        write_opt_labels(&mut out, &None);
        write_f64s(&mut out, &[1.5, -0.0]);
        let mut r = Reader::new(&out);
        assert_eq!(read_strings(&mut r).unwrap(), strings);
        assert_eq!(read_opt_labels(&mut r).unwrap(), labels);
        assert_eq!(read_opt_labels(&mut r).unwrap(), None);
        let f = read_f64s(&mut r).unwrap();
        assert_eq!(f[0], 1.5);
        assert_eq!(f[1].to_bits(), (-0.0f64).to_bits());
        assert_eq!(r.pos, out.len());
    }

    #[test]
    fn bad_utf8_is_corrupt() {
        let mut out = Vec::new();
        out.extend_from_slice(&1u64.to_le_bytes());
        out.extend_from_slice(&2u64.to_le_bytes());
        out.extend_from_slice(&[0xff, 0xfe]);
        let mut r = Reader::new(&out);
        assert!(matches!(read_strings(&mut r), Err(WireError::Corrupt(_))));
    }

    #[test]
    fn line_reader_handles_crlf_caps_and_final_line() {
        let mut lr = LineReader::new(&b"alpha\r\nbeta\ntail"[..]);
        assert_eq!(lr.read_line().unwrap().as_deref(), Some("alpha"));
        assert_eq!(lr.read_line().unwrap().as_deref(), Some("beta"));
        // final unterminated line is still delivered before clean EOF
        assert_eq!(lr.read_line().unwrap().as_deref(), Some("tail"));
        assert_eq!(lr.read_line().unwrap(), None);

        let long = vec![b'x'; MAX_LINE_BYTES + 2];
        let mut lr = LineReader::new(&long[..]);
        let err = lr.read_line().unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidData);
    }

    #[test]
    fn serve_requests_parse_strictly() {
        assert_eq!(ServeRequest::parse("topics extra junk", 4), Ok(ServeRequest::Topics));
        assert_eq!(
            ServeRequest::parse("TOPTERMS 2", 4),
            Ok(ServeRequest::TopTerms { topic: 2, n: 5 })
        );
        assert_eq!(
            ServeRequest::parse("docs 1 9", 4),
            Ok(ServeRequest::Docs { topic: 1, n: 9 })
        );
        assert_eq!(
            ServeRequest::parse("TOPTERMS 9", 4).unwrap_err(),
            "ERR topic 9 out of range (k=4)"
        );
        assert_eq!(
            ServeRequest::parse("TOPTERMS 1 0", 4).unwrap_err(),
            "ERR n must be >= 1 (usage: TOPTERMS <topic> [n])"
        );
        assert_eq!(
            ServeRequest::parse("TOPTERMS 1 2 3", 4).unwrap_err(),
            "ERR trailing arguments (usage: TOPTERMS <topic> [n])"
        );
        assert_eq!(
            ServeRequest::parse("CLASSIFY a b", 4),
            Ok(ServeRequest::Classify { words: vec!["a", "b"] })
        );
        assert_eq!(
            ServeRequest::parse("CLASSIFY", 4).unwrap_err(),
            "ERR usage: CLASSIFY <word> ..."
        );
        assert_eq!(
            ServeRequest::parse("FOLDIN cat:2 dog:0.5", 4),
            Ok(ServeRequest::FoldIn {
                doc: vec![("cat", 2.0), ("dog", 0.5)]
            })
        );
        assert_eq!(
            ServeRequest::parse("FOLDIN cat:zero", 4).unwrap_err(),
            "ERR usage: FOLDIN <word:count> ... (bad count \"zero\" in \"cat:zero\")"
        );
        assert_eq!(
            ServeRequest::parse("FOLDIN nocolon", 4).unwrap_err(),
            "ERR usage: FOLDIN <word:count> ... (bad pair \"nocolon\")"
        );
        assert_eq!(ServeRequest::parse("BATCH 3", 4), Ok(ServeRequest::Batch { n: 3 }));
        assert_eq!(
            ServeRequest::parse("BATCH 0", 4).unwrap_err(),
            format!("ERR usage: BATCH <n> (1..={MAX_BATCH})")
        );
        assert_eq!(ServeRequest::parse("", 4).unwrap_err(), "ERR empty command");
        assert_eq!(
            ServeRequest::parse("FROB", 4).unwrap_err(),
            "ERR unknown command \"FROB\""
        );
    }

    #[test]
    fn admin_requests_parse_strictly() {
        assert_eq!(AdminRequest::parse("health"), Ok(AdminRequest::Health));
        assert_eq!(
            AdminRequest::parse("RELOAD /tmp/m.esnmf"),
            Ok(AdminRequest::Reload {
                path: "/tmp/m.esnmf".to_string()
            })
        );
        assert_eq!(
            AdminRequest::parse("RELOAD").unwrap_err(),
            "ERR usage: RELOAD <path.esnmf>"
        );
        assert_eq!(
            AdminRequest::parse("RELOAD a b").unwrap_err(),
            "ERR usage: RELOAD <path.esnmf>"
        );
        assert_eq!(
            AdminRequest::parse("SHUTDOWN").unwrap_err(),
            "ERR unknown admin command \"SHUTDOWN\""
        );
        assert_eq!(AdminRequest::parse("progress"), Ok(AdminRequest::Progress));
        assert_eq!(
            AdminRequest::parse("TRACEDUMP"),
            Ok(AdminRequest::TraceDump)
        );
    }

    #[test]
    fn v3_replies_refuse_truncated_summaries() {
        // a v2-shaped Selected (no trailing summary) must decode as
        // truncated, not silently default — the handshake pins versions,
        // so a missing summary means a corrupt frame
        let msg = WorkerMsg::Selected {
            scratch_lens: vec![3],
            positives: 2,
            heap: vec![1.0],
            summary: WorkerSummary {
                compute_us: 9,
                items: 2,
            },
        };
        let mut buf = Vec::new();
        write_msg(&mut buf, &msg).unwrap();
        // strip the 16-byte summary and patch the frame length down
        let payload_len = buf.len() - 9 - 16;
        buf.truncate(buf.len() - 16);
        buf[5..9].copy_from_slice(&(payload_len as u32).to_le_bytes());
        assert!(matches!(
            read_msg(&mut &buf[..]),
            Err(crate::EsnmfError::Wire(WireError::Truncated { .. }))
        ));
    }

    fn roundtrip(msg: &WorkerMsg) -> WorkerMsg {
        let mut buf = Vec::new();
        write_msg(&mut buf, msg).unwrap();
        let mut cursor = &buf[..];
        let back = read_msg(&mut cursor).unwrap();
        assert!(cursor.is_empty(), "frame left trailing bytes");
        back
    }

    #[test]
    fn worker_frames_roundtrip() {
        let factor = Csr::from_dense(2, 2, &[1.0, 0.0, 0.25, -3.5]);
        let msgs = vec![
            WorkerMsg::Hello {
                version: WORKER_PROTOCOL_VERSION,
                digest: 0xdead_beef_cafe_f00d,
                n_terms: 12,
                n_docs: 34,
                objective: ObjectiveKind::Frobenius,
            },
            WorkerMsg::Hello {
                version: WORKER_PROTOCOL_VERSION,
                digest: 1,
                n_terms: 2,
                n_docs: 3,
                objective: ObjectiveKind::Kl,
            },
            WorkerMsg::Welcome {
                version: WORKER_PROTOCOL_VERSION,
            },
            WorkerMsg::Compute(ComputeReq {
                step_u: true,
                objective: ObjectiveKind::Frobenius,
                k: 2,
                block_rows: 3,
                span: (1, 4),
                factor: factor.clone(),
                aux: vec![1.0, 0.0, 0.0, 1.0],
                prev: None,
                pass: PassReq::Select { t: 7 },
            }),
            WorkerMsg::Compute(ComputeReq {
                step_u: false,
                objective: ObjectiveKind::Kl,
                k: 2,
                block_rows: 3,
                span: (0, 1),
                factor: factor.clone(),
                aux: vec![0.5, 0.25],
                prev: Some(Csr::from_dense(3, 2, &[1.0, 0.0, 0.0, 2.0, 0.5, 0.5])),
                pass: PassReq::Emit {
                    keep_tag: 3,
                    tau: 0.125,
                },
            }),
            WorkerMsg::Compute(ComputeReq {
                step_u: false,
                objective: ObjectiveKind::Frobenius,
                k: 2,
                block_rows: 3,
                span: (0, 1),
                factor,
                aux: vec![0.5; 4],
                prev: None,
                pass: PassReq::Emit {
                    keep_tag: 3,
                    tau: 0.125,
                },
            }),
            WorkerMsg::Selected {
                scratch_lens: vec![6, 0, 4],
                positives: 11,
                heap: vec![0.5, 1.5, 2.5],
                summary: WorkerSummary {
                    compute_us: 1234,
                    items: 10,
                },
            },
            WorkerMsg::Fragments {
                emits: vec![WireEmit {
                    row_nnz: vec![2, 0, 1],
                    indices: vec![0, 1, 1],
                    values: vec![1.0, 2.0, 3.0],
                    scratch_len: 6,
                }],
                summary: WorkerSummary {
                    compute_us: u64::MAX,
                    items: 3,
                },
            },
            WorkerMsg::Refuse {
                message: "corpus digest mismatch".to_string(),
            },
            WorkerMsg::Ping,
            WorkerMsg::Pong,
            WorkerMsg::Shutdown,
        ];
        for msg in &msgs {
            assert_eq!(&roundtrip(msg), msg);
        }
    }

    #[test]
    fn nan_tau_survives_the_wire_bit_exact() {
        // Exact-mode emission ships tau = NaN when there is no cutoff;
        // the keep predicate distinguishes NaN payloads by bit pattern.
        let msg = WorkerMsg::Compute(ComputeReq {
            step_u: true,
            objective: ObjectiveKind::Frobenius,
            k: 1,
            block_rows: 1,
            span: (0, 1),
            factor: Csr::zeros(1, 1),
            aux: vec![1.0],
            prev: None,
            pass: PassReq::Emit {
                keep_tag: 0,
                tau: f32::NAN,
            },
        });
        match roundtrip(&msg) {
            WorkerMsg::Compute(req) => match req.pass {
                PassReq::Emit { tau, .. } => {
                    assert_eq!(tau.to_bits(), f32::NAN.to_bits());
                }
                other => panic!("wrong pass {other:?}"),
            },
            other => panic!("wrong frame {other:?}"),
        }
    }

    #[test]
    fn corrupt_worker_frames_are_typed_refusals() {
        let mut buf = Vec::new();
        write_msg(&mut buf, &WorkerMsg::Ping).unwrap();

        // wrong magic
        let mut bad = buf.clone();
        bad[0] = b'X';
        assert!(matches!(
            read_msg(&mut &bad[..]),
            Err(crate::EsnmfError::Wire(WireError::Corrupt(_)))
        ));

        // unknown tag
        let mut bad = buf.clone();
        bad[4] = 99;
        assert!(matches!(
            read_msg(&mut &bad[..]),
            Err(crate::EsnmfError::Wire(WireError::Corrupt(_)))
        ));

        // length overrun claim
        let mut bad = buf.clone();
        bad[5..9].copy_from_slice(&(u32::MAX).to_le_bytes());
        assert!(matches!(
            read_msg(&mut &bad[..]),
            Err(crate::EsnmfError::Wire(WireError::Corrupt(_)))
        ));

        // truncated stream mid-frame surfaces as I/O, not a hang
        let mut framed = Vec::new();
        write_msg(
            &mut framed,
            &WorkerMsg::Refuse {
                message: "x".to_string(),
            },
        )
        .unwrap();
        framed.truncate(framed.len() - 1);
        assert!(matches!(
            read_msg(&mut &framed[..]),
            Err(crate::EsnmfError::Io(_))
        ));

        // trailing payload bytes are corrupt, not silently ignored
        let mut padded = Vec::new();
        padded.extend_from_slice(&WORKER_MAGIC);
        padded.push(7); // Ping carries no payload
        padded.extend_from_slice(&1u32.to_le_bytes());
        padded.push(0);
        assert!(matches!(
            read_msg(&mut &padded[..]),
            Err(crate::EsnmfError::Wire(WireError::Corrupt(_)))
        ));
    }

    #[test]
    fn unknown_objective_tags_are_corrupt_not_a_default() {
        // a Hello from a future objective must be refused typed — pairing
        // it as Frobenius would run the wrong per-block math
        let hello = WorkerMsg::Hello {
            version: WORKER_PROTOCOL_VERSION,
            digest: 5,
            n_terms: 1,
            n_docs: 1,
            objective: ObjectiveKind::Kl,
        };
        let mut buf = Vec::new();
        write_msg(&mut buf, &hello).unwrap();
        *buf.last_mut().unwrap() = 0x7f; // the objective tag is Hello's final byte
        match read_msg(&mut &buf[..]) {
            Err(crate::EsnmfError::Wire(WireError::Corrupt(msg))) => {
                assert!(msg.contains("objective"), "{msg}");
            }
            other => panic!("{other:?}"),
        }

        // same for the objective byte of a Compute frame (payload offset
        // 1, right after the step flag)
        let req = WorkerMsg::Compute(ComputeReq {
            step_u: true,
            objective: ObjectiveKind::Frobenius,
            k: 1,
            block_rows: 1,
            span: (0, 1),
            factor: Csr::zeros(1, 1),
            aux: vec![1.0],
            prev: None,
            pass: PassReq::Select { t: 1 },
        });
        let mut buf = Vec::new();
        write_msg(&mut buf, &req).unwrap();
        buf[9 + 1] = 0x7f;
        match read_msg(&mut &buf[..]) {
            Err(crate::EsnmfError::Wire(WireError::Corrupt(msg))) => {
                assert!(msg.contains("objective"), "{msg}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn bad_prev_factor_flag_is_corrupt() {
        let req = WorkerMsg::Compute(ComputeReq {
            step_u: true,
            objective: ObjectiveKind::Frobenius,
            k: 1,
            block_rows: 1,
            span: (0, 1),
            factor: Csr::zeros(1, 1),
            aux: vec![1.0],
            prev: None,
            pass: PassReq::Select { t: 1 },
        });
        let mut buf = Vec::new();
        write_msg(&mut buf, &req).unwrap();
        *buf.last_mut().unwrap() = 9; // the prev flag is Compute's final byte
        match read_msg(&mut &buf[..]) {
            Err(crate::EsnmfError::Wire(WireError::Corrupt(msg))) => {
                assert!(msg.contains("prev"), "{msg}");
            }
            other => panic!("{other:?}"),
        }
    }
}
