//! Model persistence: the versioned `.esnmf` binary snapshot format and
//! its checkpoint/resume plumbing.
//!
//! The paper's algorithms make NMF viable on *large* corpora — but a
//! large factorization that cannot be saved must be recomputed on every
//! process start, and a crashed run loses every iteration. [`snapshot`]
//! makes a completed (or in-flight) factorization a single portable
//! file: both CSR factors bit-exact, the vocabulary, document labels,
//! the [`crate::nmf::NmfOptions`] used, a corpus digest that pins which
//! data the factors belong to, and the convergence telemetry needed to
//! resume mid-run.

pub mod snapshot;

pub use snapshot::{
    corpus_digest, Progress, Snapshot, SnapshotError, MAX_SNAPSHOT_K, SNAPSHOT_VERSION,
};
