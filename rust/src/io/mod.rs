//! Binary persistence formats: the versioned `.esnmf` model snapshot
//! with its checkpoint/resume plumbing, and the versioned `.estdm`
//! out-of-core corpus store streamed by the blocked ALS.
//!
//! The paper's algorithms make NMF viable on *large* corpora — but a
//! large factorization that cannot be saved must be recomputed on every
//! process start, and a crashed run loses every iteration. [`snapshot`]
//! makes a completed (or in-flight) factorization a single portable
//! file: both CSR factors bit-exact, the vocabulary, document labels,
//! the [`crate::nmf::NmfOptions`] used, a corpus digest that pins which
//! data the factors belong to, and the convergence telemetry needed to
//! resume mid-run. [`store`] does the complementary thing for the
//! *input*: the term-document matrix lives on disk as row-range shards
//! in both orientations, so corpora that don't fit in RAM factorize by
//! streaming — bit-identical to in-memory. Both formats share the
//! bounds-checked codecs in [`wire`].

pub mod snapshot;
pub mod store;
pub mod wire;

pub use snapshot::{
    corpus_digest, Progress, Snapshot, SnapshotError, MAX_SNAPSHOT_K, SNAPSHOT_VERSION,
};
pub use store::{CorpusStore, ResidentCounter, ShardedMatrix, StoreError, STORE_VERSION};
