//! The `.estdm` out-of-core corpus store: the term-document matrix as
//! on-disk row-range shards, streamed by the blocked ALS half-steps.
//!
//! PR 4 bounded the solver's *intermediate* memory at O(block_rows · k),
//! but the data matrix `A` itself still had to be fully resident. This
//! store removes that last O(nnz(A)) residency: `esnmf ingest` writes a
//! corpus to disk once, and `factorize --corpus-store` streams it back
//! shard-by-shard through the [`RowSource`] contract — bit-identical to
//! the in-memory factorization, with resident corpus bytes bounded by
//! the shards currently cached across workers (one per worker cursor).
//!
//! # File layout (all integers little-endian)
//!
//! ```text
//! magic     6 bytes   b"ESTDM\0"
//! version   u16       STORE_VERSION (readers refuse newer files)
//! meta_len  u64       metadata byte count
//! meta_crc  u32       CRC-32 (IEEE) of the metadata
//! metadata  meta_len bytes
//! shards    concatenated shard payloads (offsets in the metadata)
//! ```
//!
//! The metadata holds the corpus digest (the same
//! [`corpus_digest`](super::corpus_digest) the `.esnmf` snapshot pins,
//! so `--resume` / `--warm-start` / `serve --model` verification keeps
//! working against a store), `‖A‖²_F` (precomputed with
//! [`Csr::fro_norm_sq`]'s summation order so the error history is
//! bit-identical), the vocabulary and document labels, and **two shard
//! indexes** — one per orientation:
//!
//! * **terms-major** — row ranges of `A` (terms × docs), streamed by the
//!   update-U half-step (`A·V`);
//! * **docs-major** — row ranges of `Aᵀ` (docs × terms), streamed by the
//!   update-V half-step (`Aᵀ·U`).
//!
//! Each half-step walks a different side of `A`, so the store keeps both
//! orientations on disk — disk is traded for the transpose that an
//! in-memory [`TermDocMatrix`](crate::text::TermDocMatrix) keeps as its
//! CSC twin. Every shard is a [`Csr::write_bytes`] payload of its row
//! range with its own CRC-32 in the index, and the index gives O(1) seek
//! to the shard holding any row (`row / shard_rows`).
//!
//! # Totality and failure model
//!
//! [`CorpusStore::open`] is total: truncation anywhere in the file
//! (header, metadata, or a shard region shorter than the index claims),
//! metadata bit flips (CRC), absurd section sizes and inconsistent shard
//! indexes all surface as a typed [`StoreError`]. Shard payloads are
//! CRC-checked and structurally validated on every read;
//! [`CorpusStore::verify`] runs that check over the whole file up front.
//!
//! A shard that turns unreadable *mid-run* (disk failure, or a bit flip
//! after `open`) must not panic: by then hours of compute may be in
//! flight, and the `RowSource` contract ([`RowSource::load`]) has no
//! error channel by design — the hot loops stay branch-free. Instead the
//! failed read is **latched**: the first [`StoreError`] is recorded in a
//! poison slot shared by both orientations, and the unreadable shard is
//! served as a shape-correct, all-empty row range (empty rows are
//! skipped by every streaming kernel, so the solver finishes its step
//! on partial data instead of crashing). Callers that care — the ALS
//! run loop, the serve path — check [`CorpusStore::error`] between
//! steps, keep their last consistent state, and surface the fault as an
//! error; results computed after a latched fault are never silently
//! reported as clean.

use super::snapshot::crc32;
use super::wire::{self, Reader, WireError};
use crate::sparse::{Csr, RowCursor, RowSource, RowsRef};
use crate::text::TermDocMatrix;
use std::fmt;
use std::fs::File;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, PoisonError};

/// Current format version. Bump on any layout change.
pub const STORE_VERSION: u16 = 1;

const MAGIC: &[u8; 6] = b"ESTDM\0";

/// Header bytes before the metadata: magic + version + meta_len + crc.
const HEADER_LEN: usize = 6 + 2 + 8 + 4;

/// `--shard-rows auto`: target payload bytes per shard. Small enough
/// that a handful of cached shards is negligible next to the factors,
/// large enough that seeks amortize (a shard is one contiguous read).
pub const AUTO_SHARD_BYTES: usize = 256 * 1024;

/// Everything that can go wrong opening, validating or reading a store.
#[derive(Debug)]
pub enum StoreError {
    Io(std::io::Error),
    /// Not an `.estdm` file at all.
    BadMagic,
    /// Written by a newer esnmf than this reader.
    UnsupportedVersion(u16),
    /// File ends before the declared metadata or shard region does.
    Truncated { expected: usize, have: usize },
    /// Stored bytes do not match their checksum (bit rot / flip).
    CrcMismatch {
        what: String,
        stored: u32,
        computed: u32,
    },
    /// Checksums pass but a section does not parse or is inconsistent.
    Corrupt(String),
    /// The store is valid but does not belong to this model/config.
    Mismatch(String),
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StoreError::Io(e) => write!(f, "corpus store i/o: {e}"),
            StoreError::BadMagic => write!(f, "not an .estdm corpus store (bad magic)"),
            StoreError::UnsupportedVersion(v) => write!(
                f,
                "corpus store version {v} is newer than this build (max {STORE_VERSION})"
            ),
            StoreError::Truncated { expected, have } => write!(
                f,
                "corpus store truncated: expected {expected} bytes, have {have}"
            ),
            StoreError::CrcMismatch {
                what,
                stored,
                computed,
            } => write!(
                f,
                "corpus store checksum mismatch in {what} (stored {stored:#010x}, computed {computed:#010x}) — file is corrupt"
            ),
            StoreError::Corrupt(msg) => write!(f, "corpus store corrupt: {msg}"),
            StoreError::Mismatch(msg) => write!(f, "corpus store mismatch: {msg}"),
        }
    }
}

impl std::error::Error for StoreError {}

impl From<std::io::Error> for StoreError {
    fn from(e: std::io::Error) -> Self {
        StoreError::Io(e)
    }
}

impl From<WireError> for StoreError {
    fn from(e: WireError) -> Self {
        match e {
            WireError::Truncated { expected, have } => StoreError::Truncated { expected, have },
            WireError::Corrupt(msg) => StoreError::Corrupt(msg),
        }
    }
}

/// Peak/current accounting of corpus bytes materialized from the store —
/// the out-of-core counterpart of
/// [`MemoryStats::max_intermediate_nnz`](crate::nmf::memory::MemoryStats).
/// Worker cursors charge a shard's payload bytes while they cache it and
/// release the charge when the cache is replaced or dropped, so the peak
/// is the high-water mark of shards simultaneously in flight.
#[derive(Debug, Default)]
pub struct ResidentCounter {
    current: AtomicUsize,
    peak: AtomicUsize,
    /// per-read shard-cache outcomes across every cursor of the store —
    /// a high miss share means cursors are thrashing shards (block
    /// geometry misaligned with shard heights)
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ResidentCounter {
    fn add(&self, bytes: usize) {
        let now = self.current.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak.fetch_max(now, Ordering::Relaxed);
    }

    fn sub(&self, bytes: usize) {
        self.current.fetch_sub(bytes, Ordering::Relaxed);
    }

    fn note_read(&self, hit: bool) {
        if hit {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn current(&self) -> usize {
        self.current.load(Ordering::Relaxed)
    }

    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    /// Shard reads served from a cursor's cache.
    pub fn cache_hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Shard reads that went to disk.
    pub fn cache_misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

/// Subtracts the cached shard's bytes on drop, so replacing or
/// discarding a worker's cache can never leak the resident charge.
#[derive(Debug)]
struct ResidentCharge {
    counter: Arc<ResidentCounter>,
    bytes: usize,
}

impl ResidentCharge {
    fn new(counter: &Arc<ResidentCounter>, bytes: usize) -> Self {
        counter.add(bytes);
        ResidentCharge {
            counter: Arc::clone(counter),
            bytes,
        }
    }
}

impl Drop for ResidentCharge {
    fn drop(&mut self) {
        self.counter.sub(self.bytes);
    }
}

/// A worker cursor's cached shard, parked in [`RowCursor::cache`].
struct CachedShard {
    /// (matrix token, shard ordinal) — tokens are globally unique per
    /// [`ShardedMatrix`], so a cursor crossing sources can never serve a
    /// stale shard
    key: (u64, usize),
    rows: Csr,
    _charge: ResidentCharge,
}

/// One shard's index entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardEntry {
    pub row_lo: usize,
    pub row_hi: usize,
    /// byte offset inside the shard region (after the metadata)
    pub offset: usize,
    pub len: usize,
    pub crc: u32,
}

static NEXT_MATRIX_TOKEN: AtomicU64 = AtomicU64::new(1);

/// One on-disk orientation of the corpus: fixed-height row-range shards
/// of a CSR matrix, readable through [`RowSource`]. Reads go through
/// positioned I/O on a shared file handle, so any number of worker
/// cursors stream concurrently without seeking over each other.
pub struct ShardedMatrix {
    file: Arc<File>,
    path: PathBuf,
    /// absolute file offset of the shard region
    shard_base: u64,
    rows: usize,
    cols: usize,
    nnz: usize,
    shard_rows: usize,
    shards: Vec<ShardEntry>,
    resident: Arc<ResidentCounter>,
    /// first mid-run read failure, latched; shared by both orientations
    /// of one store so one check observes either stream's fault
    errors: Arc<Mutex<Option<StoreError>>>,
    token: u64,
}

impl ShardedMatrix {
    /// Largest single shard payload, in bytes — the unit the resident
    /// bound is stated in.
    pub fn max_shard_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.len).max().unwrap_or(0)
    }

    /// Total shard payload bytes of this orientation.
    pub fn payload_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.len).sum()
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shard_rows(&self) -> usize {
        self.shard_rows
    }

    /// Read and validate shard `sid` from disk: CRC over the payload,
    /// structural CSR validation, and shape agreement with the index.
    fn read_shard(&self, sid: usize) -> Result<Csr, StoreError> {
        let entry = &self.shards[sid];
        let mut buf = vec![0u8; entry.len];
        read_exact_at(&self.file, &mut buf, self.shard_base + entry.offset as u64)?;
        let computed = crc32(&buf);
        if computed != entry.crc {
            return Err(StoreError::CrcMismatch {
                what: format!("shard {sid} (rows {}..{})", entry.row_lo, entry.row_hi),
                stored: entry.crc,
                computed,
            });
        }
        let mut pos = 0usize;
        let m = Csr::read_bytes(&buf, &mut pos).map_err(StoreError::Corrupt)?;
        if pos != buf.len() {
            return Err(StoreError::Corrupt(format!(
                "shard {sid}: {} trailing bytes",
                buf.len() - pos
            )));
        }
        if m.rows != entry.row_hi - entry.row_lo || m.cols != self.cols {
            return Err(StoreError::Corrupt(format!(
                "shard {sid} shape ({}, {}) disagrees with the index ({}, {})",
                m.rows,
                m.cols,
                entry.row_hi - entry.row_lo,
                self.cols
            )));
        }
        Ok(m)
    }

    /// The cursor's cached parse of shard `sid`, reading it if the cache
    /// holds a different shard (or another matrix's). A read failure is
    /// latched (see [`ShardedMatrix::error`]) and served as an all-empty
    /// row range of the shard's exact shape — see the module docs'
    /// failure model.
    fn cached<'c>(
        &self,
        slot: &'c mut Option<Box<dyn std::any::Any + Send>>,
        sid: usize,
    ) -> &'c Csr {
        let hit = slot
            .as_ref()
            .and_then(|b| b.downcast_ref::<CachedShard>())
            .is_some_and(|c| c.key == (self.token, sid));
        self.resident.note_read(hit);
        if !hit {
            // release the previous shard *before* any new bytes exist, and
            // charge the incoming shard before reading it, so the counter
            // also covers the raw read buffer's lifetime — old and new
            // shards never coexist and the accounted peak stays an honest
            // upper bound on cached payload bytes. (During the parse the
            // raw buffer and the decoded arrays briefly coexist, ≈ 2× one
            // shard payload of transient heap; the counter charges the
            // payload once — size real memory budgets accordingly.)
            *slot = None;
            let charge = ResidentCharge::new(&self.resident, self.shards[sid].len);
            let rows = self.read_shard(sid).unwrap_or_else(|e| {
                self.latch_error(sid, e);
                let entry = &self.shards[sid];
                empty_rows(entry.row_hi - entry.row_lo, self.cols)
            });
            *slot = Some(Box::new(CachedShard {
                key: (self.token, sid),
                rows,
                _charge: charge,
            }));
        }
        &slot
            .as_ref()
            .unwrap()
            .downcast_ref::<CachedShard>()
            .unwrap()
            .rows
    }

    /// Record a mid-run read failure. Only the first fault is kept (it
    /// is the diagnostic one — later failures are usually the same
    /// corruption rediscovered by other cursors); every occurrence logs.
    fn latch_error(&self, sid: usize, e: StoreError) {
        crate::log_warn!(
            "store",
            "corpus store {} shard {sid}: {e} — serving empty rows, fault latched",
            self.path.display()
        );
        let mut latched = self.errors.lock().unwrap_or_else(PoisonError::into_inner);
        if latched.is_none() {
            *latched = Some(e);
        }
    }

    /// The latched mid-run read failure, if any, rendered for operators.
    /// Shared with the sibling orientation (one store, one poison slot).
    pub fn error(&self) -> Option<String> {
        self.errors
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .as_ref()
            .map(|e| e.to_string())
    }
}

/// A shape-correct CSR holding `rows` empty rows — the sentinel served
/// for an unreadable shard. Empty rows contribute nothing to any
/// half-step product and are skipped by the streaming kernels.
fn empty_rows(rows: usize, cols: usize) -> Csr {
    Csr {
        rows,
        cols,
        indptr: vec![0; rows + 1],
        indices: Vec::new(),
        values: Vec::new(),
    }
}

impl RowSource for ShardedMatrix {
    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.cols
    }

    fn nnz(&self) -> usize {
        self.nnz
    }

    fn load<'a>(&'a self, lo: usize, hi: usize, cur: &'a mut RowCursor) -> RowsRef<'a> {
        assert!(lo <= hi && hi <= self.rows, "row range {lo}..{hi} out of bounds");
        if lo == hi {
            cur.begin_chunk();
            return cur.chunk_view();
        }
        let s0 = lo / self.shard_rows;
        let s1 = (hi - 1) / self.shard_rows;
        if s0 == s1 {
            // the whole range lives in one shard: serve a borrowed view
            // of the cursor's cache, zero copies
            let base = self.shards[s0].row_lo;
            let shard = self.cached(&mut cur.cache, s0);
            let (l, h) = (lo - base, hi - base);
            return RowsRef::new(
                &shard.indptr[l..=h],
                &shard.indices[shard.indptr[l]..shard.indptr[h]],
                &shard.values[shard.indptr[l]..shard.indptr[h]],
            );
        }
        // the range straddles shards: copy the covered rows into the
        // cursor's chunk buffers (bounded by the range height), paging
        // one shard through the cache at a time
        cur.indptr.clear();
        cur.indices.clear();
        cur.values.clear();
        cur.indptr.push(0);
        for sid in s0..=s1 {
            let base = self.shards[sid].row_lo;
            let top = self.shards[sid].row_hi;
            let shard = self.cached(&mut cur.cache, sid);
            for r in lo.max(base)..hi.min(top) {
                let (idx, val) = shard.row(r - base);
                cur.indices.extend_from_slice(idx);
                cur.values.extend_from_slice(val);
                cur.indptr.push(cur.values.len());
            }
        }
        RowsRef::new(&cur.indptr, &cur.indices, &cur.values)
    }
}

/// Positioned read: `pread` on unix (thread-safe on a shared handle); a
/// locked seek+read fallback elsewhere.
#[cfg(unix)]
fn read_exact_at(file: &File, buf: &mut [u8], offset: u64) -> std::io::Result<()> {
    use std::os::unix::fs::FileExt;
    file.read_exact_at(buf, offset)
}

#[cfg(not(unix))]
fn read_exact_at(file: &File, buf: &mut [u8], offset: u64) -> std::io::Result<()> {
    use std::io::{Read, Seek, SeekFrom};
    use std::sync::Mutex;
    static LOCK: Mutex<()> = Mutex::new(());
    let _g = LOCK.lock().unwrap();
    let mut f = file;
    f.seek(SeekFrom::Start(offset))?;
    f.read_exact(buf)
}

/// An opened `.estdm` store: metadata resident, the matrix on disk in
/// both orientations.
pub struct CorpusStore {
    pub terms: Vec<String>,
    pub doc_labels: Option<Vec<u32>>,
    pub label_names: Vec<String>,
    corpus_digest: u64,
    norm_a_sq: f64,
    terms_major: ShardedMatrix,
    docs_major: ShardedMatrix,
    resident: Arc<ResidentCounter>,
    /// the poison slot shared by both orientations (see the module
    /// docs' failure model)
    errors: Arc<Mutex<Option<StoreError>>>,
    path: PathBuf,
}

impl CorpusStore {
    /// Write `tdm` to `path` as a store. `shard_rows = 0` is auto: each
    /// orientation targets [`AUTO_SHARD_BYTES`] of payload per shard.
    /// The write is atomic (`.tmp` + rename), like snapshot saves — and
    /// it **streams**: shards are serialized one at a time straight into
    /// the file (extra memory O(one shard) beyond the resident `tdm`),
    /// then the metadata — whose length is fixed by the shard *counts*,
    /// not their contents — is written back over its reserved region.
    /// An out-of-core subsystem whose ingest needed several transient
    /// copies of `A` would defeat its own point.
    pub fn write(path: &Path, tdm: &TermDocMatrix, shard_rows: usize) -> Result<(), StoreError> {
        use std::io::{Seek, SeekFrom, Write};

        let a = RawCsr::of(&tdm.a);
        let at = RawCsr::transpose_of(&tdm.a_csc);
        let terms_plan = shard_plan(&a, shard_rows);
        let docs_plan = shard_plan(&at, shard_rows);

        // everything before the shard indexes is known up front — one
        // digest pass, one norm pass, one vocabulary serialization
        let mut meta = Vec::new();
        meta.extend_from_slice(&super::corpus_digest(tdm).to_le_bytes());
        meta.extend_from_slice(&tdm.a.fro_norm_sq().to_bits().to_le_bytes());
        meta.extend_from_slice(&(tdm.n_terms() as u64).to_le_bytes());
        meta.extend_from_slice(&(tdm.n_docs() as u64).to_le_bytes());
        meta.extend_from_slice(&(tdm.a.nnz() as u64).to_le_bytes());
        wire::write_strings(&mut meta, &tdm.terms);
        wire::write_opt_labels(&mut meta, &tdm.doc_labels);
        wire::write_strings(&mut meta, &tdm.label_names);
        // index entries are fixed-size (see write_shard_index: shard_rows
        // + count + 36 bytes per entry), so the metadata length is pinned
        // by the shard *counts* before the offsets/CRCs exist
        let index_bytes = |plan: &ShardPlan| 8 + 8 + 36 * plan.ranges.len();
        let meta_len = meta.len() + index_bytes(&terms_plan) + index_bytes(&docs_plan);

        let tmp = path.with_extension("estdm.tmp");
        let mut file = std::io::BufWriter::new(File::create(&tmp)?);
        // reserve the header + metadata region, stream the shards after it
        file.seek(SeekFrom::Start((HEADER_LEN + meta_len) as u64))?;
        let mut offset = 0usize;
        let mut buf = Vec::new();
        let mut stream = |plan: &ShardPlan, src: &RawCsr<'_>| -> Result<ShardIndex, StoreError> {
            let mut entries = Vec::with_capacity(plan.ranges.len());
            for &(lo, hi) in &plan.ranges {
                buf.clear();
                src.slice(lo, hi).write_bytes(&mut buf);
                file.write_all(&buf)?;
                entries.push(ShardEntry {
                    row_lo: lo,
                    row_hi: hi,
                    offset,
                    len: buf.len(),
                    crc: crc32(&buf),
                });
                offset += buf.len();
            }
            Ok((plan.shard_rows, entries))
        };
        let terms_idx = stream(&terms_plan, &a)?;
        let docs_idx = stream(&docs_plan, &at)?;

        write_shard_index(&mut meta, &terms_idx);
        write_shard_index(&mut meta, &docs_idx);
        assert_eq!(meta.len(), meta_len, "fixed-size index entries pin the length");
        file.seek(SeekFrom::Start(0))?;
        file.write_all(MAGIC)?;
        file.write_all(&STORE_VERSION.to_le_bytes())?;
        file.write_all(&(meta.len() as u64).to_le_bytes())?;
        file.write_all(&crc32(&meta).to_le_bytes())?;
        file.write_all(&meta)?;
        file.into_inner()
            .map_err(|e| StoreError::Io(e.into_error()))?
            .sync_all()?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Open a store: header, metadata CRC, and index consistency are
    /// all checked here (shard payloads are checked per read, or all at
    /// once by [`Self::verify`]).
    pub fn open(path: &Path) -> Result<CorpusStore, StoreError> {
        let file = File::open(path)?;
        let file_len = file.metadata()?.len() as usize;
        if file_len < HEADER_LEN {
            return Err(StoreError::Truncated {
                expected: HEADER_LEN,
                have: file_len,
            });
        }
        let mut header = vec![0u8; HEADER_LEN];
        read_exact_at(&file, &mut header, 0)?;
        if &header[..6] != MAGIC {
            return Err(StoreError::BadMagic);
        }
        let version = u16::from_le_bytes(header[6..8].try_into().unwrap());
        if version == 0 || version > STORE_VERSION {
            return Err(StoreError::UnsupportedVersion(version));
        }
        let meta_len = u64::from_le_bytes(header[8..16].try_into().unwrap()) as usize;
        let stored_crc = u32::from_le_bytes(header[16..20].try_into().unwrap());
        if file_len - HEADER_LEN < meta_len {
            return Err(StoreError::Truncated {
                expected: HEADER_LEN + meta_len,
                have: file_len,
            });
        }
        let mut meta = vec![0u8; meta_len];
        read_exact_at(&file, &mut meta, HEADER_LEN as u64)?;
        let computed = crc32(&meta);
        if computed != stored_crc {
            return Err(StoreError::CrcMismatch {
                what: "metadata".into(),
                stored: stored_crc,
                computed,
            });
        }

        let mut r = Reader::new(&meta);
        let corpus_digest = r.u64()?;
        let norm_a_sq = f64::from_bits(r.u64()?);
        let n_terms = r.u64()? as usize;
        let n_docs = r.u64()? as usize;
        let nnz = r.u64()? as usize;
        let terms = wire::read_strings(&mut r)?;
        let doc_labels = wire::read_opt_labels(&mut r)?;
        let label_names = wire::read_strings(&mut r)?;
        let terms_idx = read_shard_index(&mut r)?;
        let docs_idx = read_shard_index(&mut r)?;
        if r.pos != meta.len() {
            return Err(StoreError::Corrupt(format!(
                "{} unparsed metadata bytes",
                meta.len() - r.pos
            )));
        }
        if terms.len() != n_terms {
            return Err(StoreError::Corrupt(format!(
                "{} vocabulary terms for {n_terms} rows",
                terms.len()
            )));
        }
        if let Some(labels) = &doc_labels {
            if labels.len() != n_docs {
                return Err(StoreError::Corrupt(format!(
                    "{} doc labels for {n_docs} documents",
                    labels.len()
                )));
            }
            let n = label_names.len() as u32;
            if let Some(&bad) = labels.iter().find(|&&l| l >= n) {
                return Err(StoreError::Corrupt(format!(
                    "doc label id {bad} out of range ({n} label names)"
                )));
            }
        }
        validate_shard_index(&terms_idx.1, n_terms, terms_idx.0, "terms-major")?;
        validate_shard_index(&docs_idx.1, n_docs, docs_idx.0, "docs-major")?;
        // every shard must live inside the file — a truncated shard
        // region is caught here at open, not mid-factorization
        let shard_base = HEADER_LEN + meta_len;
        let region = file_len - shard_base;
        for (name, idx) in [("terms-major", &terms_idx.1), ("docs-major", &docs_idx.1)] {
            for (i, s) in idx.iter().enumerate() {
                let end = s
                    .offset
                    .checked_add(s.len)
                    .ok_or_else(|| StoreError::Corrupt(format!("{name} shard {i} offset overflow")))?;
                if end > region {
                    return Err(StoreError::Truncated {
                        expected: shard_base + end,
                        have: file_len,
                    });
                }
            }
        }

        let file = Arc::new(file);
        let resident = Arc::new(ResidentCounter::default());
        let errors = Arc::new(Mutex::new(None));
        let mk = |rows: usize, cols: usize, (shard_rows, shards): (usize, Vec<ShardEntry>)| {
            ShardedMatrix {
                file: Arc::clone(&file),
                path: path.to_path_buf(),
                shard_base: shard_base as u64,
                rows,
                cols,
                nnz,
                shard_rows,
                shards,
                resident: Arc::clone(&resident),
                errors: Arc::clone(&errors),
                token: NEXT_MATRIX_TOKEN.fetch_add(1, Ordering::Relaxed),
            }
        };
        Ok(CorpusStore {
            terms_major: mk(n_terms, n_docs, terms_idx),
            docs_major: mk(n_docs, n_terms, docs_idx),
            terms,
            doc_labels,
            label_names,
            corpus_digest,
            norm_a_sq,
            resident,
            errors,
            path: path.to_path_buf(),
        })
    }

    /// Read and CRC-check every shard of both orientations, and check
    /// the two orientations agree on the nonzero count. O(file size);
    /// run before long factorizations where a mid-run panic on bit rot
    /// would be expensive.
    pub fn verify(&self) -> Result<(), StoreError> {
        for m in [&self.terms_major, &self.docs_major] {
            let mut nnz = 0usize;
            for sid in 0..m.shards.len() {
                nnz += m.read_shard(sid)?.nnz();
            }
            if nnz != m.nnz {
                return Err(StoreError::Corrupt(format!(
                    "shards hold {nnz} nonzeros, metadata claims {}",
                    m.nnz
                )));
            }
        }
        Ok(())
    }

    /// Terms-major orientation: rows of `A` (terms × docs), the
    /// update-U half-step's stream.
    pub fn terms_major(&self) -> &ShardedMatrix {
        &self.terms_major
    }

    /// Docs-major orientation: rows of `Aᵀ` (docs × terms), the
    /// update-V half-step's stream.
    pub fn docs_major(&self) -> &ShardedMatrix {
        &self.docs_major
    }

    pub fn n_terms(&self) -> usize {
        self.terms_major.rows
    }

    pub fn n_docs(&self) -> usize {
        self.docs_major.rows
    }

    pub fn nnz(&self) -> usize {
        self.terms_major.nnz
    }

    /// The [`corpus_digest`](super::corpus_digest) recorded at ingest.
    pub fn digest(&self) -> u64 {
        self.corpus_digest
    }

    /// `‖A‖²_F` recorded at ingest (bit-identical to
    /// [`Csr::fro_norm_sq`] on the resident matrix).
    pub fn norm_a_sq(&self) -> f64 {
        self.norm_a_sq
    }

    /// Resident-corpus accounting shared by both orientations' cursors.
    pub fn resident(&self) -> &ResidentCounter {
        &self.resident
    }

    /// A shared handle to the same accounting, for observers (e.g. the
    /// factorize admin listener) that outlive or run beside the store's
    /// borrowers.
    pub fn resident_shared(&self) -> Arc<ResidentCounter> {
        Arc::clone(&self.resident)
    }

    /// The latched mid-run read failure across both orientations, if
    /// any, rendered for operators/logs. While this is `Some`, results
    /// streamed from the store are incomplete (unreadable shards served
    /// as empty rows) and must not be reported as clean.
    pub fn error(&self) -> Option<String> {
        self.errors
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .as_ref()
            .map(|e| e.to_string())
    }

    /// Take ownership of the latched fault (clearing it), e.g. to
    /// propagate as a typed error after checkpointing last-good state.
    pub fn take_error(&self) -> Option<StoreError> {
        self.errors
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .take()
    }

    /// Total shard payload bytes (both orientations) — what "the whole
    /// matrix resident" would cost; the streaming peak must undercut it.
    pub fn payload_bytes(&self) -> usize {
        self.terms_major.payload_bytes() + self.docs_major.payload_bytes()
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// One orientation's index: the shard height and its entries.
type ShardIndex = (usize, Vec<ShardEntry>);

/// Borrowed CSR-shaped view of one orientation at ingest time — the CSC
/// twin serializes as the CSR of `Aᵀ` without being cloned into one.
struct RawCsr<'a> {
    rows: usize,
    cols: usize,
    indptr: &'a [usize],
    indices: &'a [u32],
    values: &'a [f32],
}

impl<'a> RawCsr<'a> {
    fn of(m: &'a Csr) -> Self {
        RawCsr {
            rows: m.rows,
            cols: m.cols,
            indptr: &m.indptr,
            indices: &m.indices,
            values: &m.values,
        }
    }

    /// CSC of `A` is, field for field, the CSR of `Aᵀ`.
    fn transpose_of(c: &'a crate::sparse::Csc) -> Self {
        RawCsr {
            rows: c.cols,
            cols: c.rows,
            indptr: &c.indptr,
            indices: &c.indices,
            values: &c.values,
        }
    }

    /// Copy rows `lo..hi` into a standalone one-shard CSR (indptr
    /// rebased) — the only per-shard allocation of the streaming write.
    fn slice(&self, lo: usize, hi: usize) -> Csr {
        let base = self.indptr[lo];
        Csr {
            rows: hi - lo,
            cols: self.cols,
            indptr: self.indptr[lo..=hi].iter().map(|&p| p - base).collect(),
            indices: self.indices[base..self.indptr[hi]].to_vec(),
            values: self.values[base..self.indptr[hi]].to_vec(),
        }
    }
}

/// One orientation's sharding decision: the resolved height and the row
/// ranges (a zero-row orientation still gets one empty shard so load
/// logic never meets a missing index).
struct ShardPlan {
    shard_rows: usize,
    ranges: Vec<(usize, usize)>,
}

/// Resolve `--shard-rows N|auto` for one orientation (auto targets
/// [`AUTO_SHARD_BYTES`] of payload from the average bytes-per-row) and
/// lay out the row ranges.
fn shard_plan(m: &RawCsr<'_>, shard_rows: usize) -> ShardPlan {
    let resolved = if shard_rows != 0 {
        shard_rows
    } else if m.rows == 0 {
        1
    } else {
        // payload ≈ 24 header + 8·(rows+1) indptr + 12·nnz entries
        let bytes_per_row = 8 + 12 * m.values.len() / m.rows.max(1);
        (AUTO_SHARD_BYTES / bytes_per_row.max(1)).clamp(1, m.rows.max(1))
    };
    let mut ranges = crate::coordinator::pool::fixed_chunks(m.rows, resolved);
    if m.rows == 0 {
        ranges.push((0, 0));
    }
    ShardPlan {
        shard_rows: resolved,
        ranges,
    }
}

fn write_shard_index(out: &mut Vec<u8>, (shard_rows, entries): &ShardIndex) {
    out.extend_from_slice(&(*shard_rows as u64).to_le_bytes());
    out.extend_from_slice(&(entries.len() as u64).to_le_bytes());
    for e in entries {
        out.extend_from_slice(&(e.row_lo as u64).to_le_bytes());
        out.extend_from_slice(&(e.row_hi as u64).to_le_bytes());
        out.extend_from_slice(&(e.offset as u64).to_le_bytes());
        out.extend_from_slice(&(e.len as u64).to_le_bytes());
        out.extend_from_slice(&e.crc.to_le_bytes());
    }
}

fn read_shard_index(r: &mut Reader) -> Result<ShardIndex, StoreError> {
    let shard_rows = r.u64()? as usize;
    let n = r.len("shard index", 36)?;
    let mut entries = Vec::with_capacity(n);
    for _ in 0..n {
        entries.push(ShardEntry {
            row_lo: r.u64()? as usize,
            row_hi: r.u64()? as usize,
            offset: r.u64()? as usize,
            len: r.u64()? as usize,
            crc: r.u32()?,
        });
    }
    Ok((shard_rows, entries))
}

/// Shards must tile `0..rows` contiguously at the declared height —
/// `load`'s `row / shard_rows` O(1) lookup depends on it.
fn validate_shard_index(
    entries: &[ShardEntry],
    rows: usize,
    shard_rows: usize,
    name: &str,
) -> Result<(), StoreError> {
    if shard_rows == 0 {
        return Err(StoreError::Corrupt(format!("{name}: zero shard height")));
    }
    let expect = if rows == 0 { 1 } else { rows.div_ceil(shard_rows) };
    if entries.len() != expect {
        return Err(StoreError::Corrupt(format!(
            "{name}: {} shards for {rows} rows at height {shard_rows} (expected {expect})",
            entries.len()
        )));
    }
    let mut prev = 0usize;
    for (i, e) in entries.iter().enumerate() {
        let want_hi = if rows == 0 { 0 } else { (prev + shard_rows).min(rows) };
        if e.row_lo != prev || e.row_hi != want_hi {
            return Err(StoreError::Corrupt(format!(
                "{name}: shard {i} covers {}..{} (expected {prev}..{want_hi})",
                e.row_lo, e.row_hi
            )));
        }
        prev = e.row_hi;
    }
    if prev != rows {
        return Err(StoreError::Corrupt(format!(
            "{name}: shards cover {prev} of {rows} rows"
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::text::TdmBuilder;

    fn tiny_tdm() -> TermDocMatrix {
        let mut b = TdmBuilder::new();
        for i in 0..8 {
            b.add_text("coffee crop quotas coffee brazil crop", Some("econ"));
            b.add_text("electrons atoms hydrogen electrons atoms", Some("sci"));
            if i % 2 == 0 {
                b.add_text("guitar chord melody guitar rhythm chord", Some("music"));
            }
        }
        b.freeze()
    }

    fn temp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("esnmf_store_{name}.estdm"))
    }

    fn write_open(name: &str, tdm: &TermDocMatrix, shard_rows: usize) -> (PathBuf, CorpusStore) {
        let path = temp(name);
        let _ = std::fs::remove_file(&path);
        CorpusStore::write(&path, tdm, shard_rows).unwrap();
        let store = CorpusStore::open(&path).unwrap();
        (path, store)
    }

    /// Reassemble one orientation through arbitrary load ranges.
    fn reassemble(m: &ShardedMatrix, step: usize) -> Csr {
        let mut cur = RowCursor::new();
        let mut indptr = vec![0usize];
        let mut indices = Vec::new();
        let mut values = Vec::new();
        let mut lo = 0;
        while lo < m.rows() {
            let hi = (lo + step).min(m.rows());
            let view = m.load(lo, hi, &mut cur);
            for i in 0..view.n_rows() {
                let (idx, val) = view.row(i);
                indices.extend_from_slice(idx);
                values.extend_from_slice(val);
                indptr.push(values.len());
            }
            lo = hi;
        }
        Csr {
            rows: m.rows(),
            cols: m.cols(),
            indptr,
            indices,
            values,
        }
    }

    #[test]
    fn roundtrip_reassembles_both_orientations_bit_exactly() {
        let tdm = tiny_tdm();
        for shard_rows in [1usize, 3, 1000] {
            let (path, store) = write_open(&format!("rt{shard_rows}"), &tdm, shard_rows);
            assert_eq!(store.n_terms(), tdm.n_terms());
            assert_eq!(store.n_docs(), tdm.n_docs());
            assert_eq!(store.nnz(), tdm.a.nnz());
            assert_eq!(store.terms, tdm.terms);
            assert_eq!(store.doc_labels, tdm.doc_labels);
            assert_eq!(store.label_names, tdm.label_names);
            assert_eq!(store.digest(), crate::io::corpus_digest(&tdm));
            assert_eq!(store.norm_a_sq().to_bits(), tdm.a.fro_norm_sq().to_bits());
            // every load granularity — within-shard, straddling, whole —
            // reproduces the matrices bit for bit
            for step in [1usize, 2, 5, tdm.n_terms().max(1)] {
                assert_eq!(reassemble(store.terms_major(), step), tdm.a, "step {step}");
                assert_eq!(
                    reassemble(store.docs_major(), step),
                    tdm.a.transpose(),
                    "step {step}"
                );
            }
            store.verify().unwrap();
            std::fs::remove_file(&path).unwrap();
        }
    }

    #[test]
    fn shard_index_gives_o1_access_to_any_row() {
        let tdm = tiny_tdm();
        let (path, store) = write_open("seek", &tdm, 2);
        let m = store.terms_major();
        assert!(m.n_shards() > 2, "corpus must span several shards");
        let mut cur = RowCursor::new();
        // single rows in arbitrary order, each served from one shard
        for r in [m.rows() - 1, 0, m.rows() / 2, 1] {
            let view = m.load(r, r + 1, &mut cur);
            assert_eq!(view.row(0), tdm.a.row(r), "row {r}");
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn resident_accounting_is_bounded_by_cached_shards() {
        let tdm = tiny_tdm();
        let (path, store) = write_open("resident", &tdm, 2);
        let m = store.terms_major();
        let max_shard = m.max_shard_bytes();
        let mut cur = RowCursor::new();
        for lo in 0..m.rows() {
            let _ = m.load(lo, (lo + 2).min(m.rows()), &mut cur);
            // one cursor ⇒ at most one shard resident at any instant
            assert!(
                store.resident().current() <= max_shard,
                "resident {} > one shard {max_shard}",
                store.resident().current()
            );
        }
        assert!(store.resident().peak() <= max_shard);
        assert!(store.resident().peak() > 0);
        // strictly below full residency on a multi-shard corpus
        assert!(store.resident().peak() < store.payload_bytes());
        drop(cur);
        assert_eq!(store.resident().current(), 0, "drop releases the charge");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn truncation_at_every_prefix_is_typed_at_open() {
        let tdm = tiny_tdm();
        let path = temp("trunc");
        let _ = std::fs::remove_file(&path);
        CorpusStore::write(&path, &tdm, 2).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let cut_path = temp("trunc_cut");
        for cut in 0..bytes.len() {
            std::fs::write(&cut_path, &bytes[..cut]).unwrap();
            match CorpusStore::open(&cut_path) {
                Err(
                    StoreError::Truncated { .. }
                    | StoreError::Corrupt(_)
                    | StoreError::CrcMismatch { .. },
                ) => {}
                other => panic!(
                    "prefix of {cut}/{} bytes: {:?}",
                    bytes.len(),
                    other.map(|_| "opened")
                ),
            }
        }
        std::fs::remove_file(&path).unwrap();
        std::fs::remove_file(&cut_path).unwrap();
    }

    #[test]
    fn every_bit_flip_is_caught() {
        let tdm = tiny_tdm();
        let path = temp("flip");
        let _ = std::fs::remove_file(&path);
        CorpusStore::write(&path, &tdm, 2).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let flip_path = temp("flip_bad");
        let n = bytes.len();
        // positions spread over header, metadata and shard region
        for pos in [0usize, 7, HEADER_LEN, HEADER_LEN + 9, n / 2, n * 3 / 4, n - 1] {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x10;
            std::fs::write(&flip_path, &bad).unwrap();
            let caught = match CorpusStore::open(&flip_path) {
                Err(_) => true,
                // flips in the shard region pass open (metadata intact)
                // but must be caught by the full-file verify
                Ok(store) => store.verify().is_err(),
            };
            assert!(caught, "flip at byte {pos} undetected");
        }
        std::fs::remove_file(&path).unwrap();
        std::fs::remove_file(&flip_path).unwrap();
    }

    #[test]
    fn shard_region_bit_flip_is_a_crc_mismatch_on_read() {
        let tdm = tiny_tdm();
        let path = temp("shardflip");
        let _ = std::fs::remove_file(&path);
        CorpusStore::write(&path, &tdm, 2).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // flip a bit in the very last shard payload byte
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let store = CorpusStore::open(&path).unwrap();
        match store.verify() {
            Err(StoreError::CrcMismatch { what, .. }) => {
                assert!(what.contains("shard"), "{what}");
            }
            other => panic!("{:?}", other.map(|_| "verified")),
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn future_version_and_bad_magic_are_refused() {
        let tdm = tiny_tdm();
        let path = temp("version");
        let _ = std::fs::remove_file(&path);
        CorpusStore::write(&path, &tdm, 0).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let mut newer = bytes.clone();
        newer[6..8].copy_from_slice(&(STORE_VERSION + 1).to_le_bytes());
        std::fs::write(&path, &newer).unwrap();
        assert!(matches!(
            CorpusStore::open(&path),
            Err(StoreError::UnsupportedVersion(_))
        ));
        let mut magic = bytes.clone();
        magic[0] = b'X';
        std::fs::write(&path, &magic).unwrap();
        assert!(matches!(CorpusStore::open(&path), Err(StoreError::BadMagic)));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn post_open_corruption_is_latched_not_a_panic() {
        let tdm = tiny_tdm();
        let path = temp("latch");
        let _ = std::fs::remove_file(&path);
        CorpusStore::write(&path, &tdm, 2).unwrap();
        let store = CorpusStore::open(&path).unwrap();
        assert!(store.error().is_none());
        // corrupt the last shard payload byte AFTER open — mid-run bit
        // rot (fs::write truncates the same inode, so the store's open
        // handle sees the corrupted bytes)
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        // stream the whole docs-major orientation (its final shard is
        // the corrupted one): the bad shard is served as shape-correct
        // empty rows instead of panicking mid-run
        let m = store.docs_major();
        let mut cur = RowCursor::new();
        let mut rows_seen = 0;
        let mut lo = 0;
        while lo < m.rows() {
            let hi = (lo + 2).min(m.rows());
            let view = m.load(lo, hi, &mut cur);
            rows_seen += view.n_rows();
            lo = hi;
        }
        assert_eq!(rows_seen, m.rows(), "shape stays correct under the fault");
        // the fault is latched and visible from every handle
        let msg = store.error().expect("fault latched");
        assert!(msg.contains("checksum mismatch"), "{msg}");
        assert!(m.error().is_some());
        assert!(
            store.terms_major().error().is_some(),
            "poison slot is shared across orientations"
        );
        assert!(matches!(
            store.take_error(),
            Some(StoreError::CrcMismatch { .. })
        ));
        assert!(store.error().is_none(), "take_error clears the latch");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn auto_shard_rows_are_positive_and_bounded() {
        let tdm = tiny_tdm();
        let (path, store) = write_open("auto", &tdm, 0);
        assert!(store.terms_major().shard_rows() >= 1);
        assert!(store.docs_major().shard_rows() >= 1);
        store.verify().unwrap();
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn empty_corpus_roundtrips() {
        let tdm = TdmBuilder::new().freeze();
        let (path, store) = write_open("empty", &tdm, 0);
        assert_eq!(store.n_terms(), 0);
        assert_eq!(store.n_docs(), 0);
        store.verify().unwrap();
        let mut cur = RowCursor::new();
        assert_eq!(store.terms_major().load(0, 0, &mut cur).n_rows(), 0);
        std::fs::remove_file(&path).unwrap();
    }
}
