//! esnmf — CLI for the Enforced Sparse NMF system.
//!
//! Subcommands:
//! * `factorize`  — run one factorization (native or XLA backend) and
//!                  print convergence + topic tables.
//! * `ingest`     — write a corpus to an on-disk `.estdm` store for
//!                  out-of-core factorization (`--corpus-store`).
//! * `experiment` — regenerate a paper figure/table (`fig1`..`fig9`,
//!                  `table1`, or `all`).
//! * `serve`      — factorize a corpus (or load a `.esnmf` snapshot),
//!                  then serve topic queries over TCP.
//! * `worker`     — join a distributed factorization as a stateless
//!                  compute worker over a shared `.estdm` store.
//! * `gen-corpus` — write a synthetic preset corpus to disk as .txt files.
//! * `artifacts`  — inspect/smoke-test the compiled XLA artifacts.
//! * `bench-check`— compare guarded metrics between two `BENCH_smoke.json`
//!                  trajectory points (the CI memory-regression gate).
//! * `bench-compare` — before/after markdown report over two trajectory
//!                  points (the PGO lane's perf report; never gates).
//!
//! Every failure funnels through [`EsnmfError`], so the process exit code
//! is the failure *category* (see `src/error.rs`): 2 = usage/config,
//! 3 = corrupt data at rest or on the wire, 4 = protocol violation
//! between live processes, 1 = everything else.

use esnmf::backend::{AlsBackend, BackendKind, NativeBackend, XlaBackend};
use esnmf::cli::Args;
use esnmf::config::{Algorithm, ConfigFile, RunConfig};
use esnmf::coordinator::{
    watch_model, AdminServer, AdminSurface, FactorizeAdmin, MetricsRegistry, Provenance,
    ServerState, TopicModel, TopicServer,
};
use esnmf::corpus::{self, Scale};
use esnmf::eval::topics::{format_topic_table, topic_term_table};
use esnmf::eval::{mean_topic_accuracy, SparsityReport};
use esnmf::experiments::{self, ExpConfig};
use esnmf::io::CorpusStore;
use esnmf::nmf::{factorize_sequential_corpus, AlsCorpus};
use esnmf::runtime::{self, ProgramKind, XlaExecutor};
use esnmf::sparse::RowSource;
use esnmf::text::TermDocMatrix;
use esnmf::util::logging;
use esnmf::{log_info, EsnmfError};
use std::sync::Arc;

/// Every CLI path funnels into the typed error surface, so `main` can
/// map failure categories to stable exit codes.
type CliResult<T = ()> = std::result::Result<T, EsnmfError>;

const USAGE: &str = r#"esnmf — Enforced Sparse Non-Negative Matrix Factorization

USAGE:
  esnmf factorize  [--corpus reuters|wikipedia|pubmed|dir:<path>] [--scale tiny|small|paper]
                   [--corpus-store c.estdm]
                   [--k N] [--iters N] [--sparsity none|both|u|v|percol] [--t-u N] [--t-v N]
                   [--objective frobenius|kl]
                   [--algorithm als|seq] [--backend native|xla] [--seed N] [--init-nnz N]
                   [--threads N|auto] [--block-rows N|auto] [--config file.toml] [--top N]
                   [--save-model m.esnmf] [--checkpoint-every N]
                   [--resume ck.esnmf] [--warm-start old.esnmf]
                   [--distributed] [--dist-workers N] [--dist-listen 127.0.0.1:7611]
                   [--dist-timeout SECS] [--trace run.trace.jsonl] [--admin-port N]

  --objective picks the per-half-step math: frobenius (default — the
  paper's enforced-sparse least-squares ALS) or kl (multiplicative
  KL-divergence updates, same top-k sparsity enforcement, reported as
  mean per-token KL). kl requires --algorithm als --backend native and
  streams through the identical block geometry, so --threads,
  --block-rows, --corpus-store and --distributed all apply unchanged.
  --threads row-partitions the ALS hot path across N workers (default:
  auto = all cores). Results are bit-identical at any thread count.
  --block-rows streams each ALS half-step over N-row blocks, bounding
  peak intermediate memory at N·k scalars per worker (default: auto =
  a fixed scratch budget / k; ESNMF_BLOCK_ROWS overrides auto).
  Factors are bit-identical at any block height — only memory
  telemetry moves.
  --corpus-store factorizes against an on-disk .estdm store (written by
  `esnmf ingest`) instead of loading the corpus into memory: each
  half-step streams A shard-by-shard, so resident corpus memory is
  bounded by the shards in flight across workers — and the factors are
  bit-identical to the in-memory run. Requires --backend native.
  --save-model persists the factorization as a versioned .esnmf snapshot
  (factors, vocabulary, labels, options, corpus digest).
  --checkpoint-every N writes that snapshot every N iterations mid-run;
  --resume continues a checkpoint (refuses on corpus/k mismatch) and
  reaches the same result as an uninterrupted run. --warm-start seeds U
  from a prior snapshot aligned by term, for incremental corpora. All
  snapshot digest checks work against a store too (its metadata carries
  the same corpus digest).
  --trace streams structured run telemetry (one versioned JSONL event
  per iteration, half-step, selection/emission pass, enforcement pass,
  checkpoint, and distributed scatter/merge/reassign, with wall time,
  nnz, tau and residual fields) to the given file; `esnmf trace-report`
  renders it. Tracing is pure telemetry — the factors digest is
  byte-identical with it on or off. --admin-port opens the loopback
  observability listener during the run: HEALTH, METRICS (Prometheus,
  incl. per-worker distributed counters and out-of-core store gauges),
  PROGRESS (iteration / residual / ETA), TRACEDUMP (the in-memory
  trace ring as JSONL).
  --distributed runs the factorization as a coordinator: it listens on
  --dist-listen, waits (up to --dist-timeout seconds) for --dist-workers
  `esnmf worker` processes that opened the *same* .estdm store, and
  scatters each half-step's block spans to them. Factors are
  bit-identical to the single-process run at any worker count; a worker
  that dies or straggles past --dist-timeout is marked dead and its
  span recomputed (by survivors, else locally), so the run always
  completes. Requires --corpus-store --backend native --algorithm als.
  esnmf worker     <corpus.estdm> [--coordinator 127.0.0.1:7611]
                   [--objective frobenius|kl] [--threads N|auto]

  Joins a distributed factorization as a stateless compute worker: opens
  the shared .estdm store, connects to the coordinator (retrying while
  it starts up), proves it sees the same corpus (digest handshake) and
  runs the same --objective (a mismatched pairing is refused before any
  work flows), then computes assigned half-step spans until told to
  shut down. Workers hold no iteration state — kill one mid-run and the
  result is still bit-identical.
  esnmf ingest     [--corpus ... --scale ... --seed N | dir:<path>]
                   [--shard-rows N|auto] --out corpus.estdm

  Writes the corpus as a versioned .estdm store: the term-document
  matrix as row-range shards in both orientations (terms-major for the
  A·V half-step, docs-major for AᵀU), with a CRC-checked shard index,
  vocabulary, labels, the corpus digest and ‖A‖². --shard-rows sets the
  rows per shard (auto targets 256 KiB payloads per shard).
  esnmf experiment <fig1|fig2|fig3|table1|fig4|fig5|fig6|fig7|fig8|fig9|all>
                   [--scale ...] [--seed N] [--fast] [--out results/]
  esnmf serve      [--addr 127.0.0.1:7878] [--model m.esnmf]
                   [--serve-threads N|auto] [--cache-size N] [--foldin-t N]
                   [--admin-port N] [--watch-model] [factorize flags]

  --model serves a saved snapshot without factorizing (cold start = one
  file read; refuses on k mismatch, and on digest mismatch when an
  explicit --corpus is also given). --serve-threads bounds the
  simultaneously served connections (default 8), --cache-size sizes the
  CLASSIFY/FOLDIN response LRU (0 disables), and --foldin-t caps the
  nonzeros of folded-in document rows (defaults to --t-v, else the
  snapshot's training budget). --admin-port opens a second,
  loopback-only listener speaking HEALTH / READY / METRICS (Prometheus
  text) / PROVENANCE / RELOAD <path> — RELOAD hot-swaps the served
  model atomically without dropping connections. --watch-model polls
  the --model file's mtime and hot-swaps when it changes. Wire
  protocol: rust/README.md.
  esnmf gen-corpus [--corpus ...] [--scale ...] [--seed N] --out <dir>
  esnmf artifacts  [--dir artifacts/]
  esnmf bench-check --previous prev.json --current BENCH_smoke.json
                   [--tolerance 1.10]
                   [--guards max_intermediate_nnz,resident_corpus,p99_us]
                   [--absolute trace.overhead_x=1.05,...]

  Compares the guarded (lower-is-better) metrics of two merged
  bench-smoke trajectory documents and exits nonzero when any grew
  beyond the tolerance factor — the CI memory- and latency-regression
  gate (guards are substring matches; `p99_us` covers the serving-plane
  latency metrics). A missing --previous, or one whose "suites" map is
  empty (the committed BENCH_smoke.json seed), records the current
  document as the baseline and passes. `wall_s` guards the benchmark
  wall-time medians (use a looser --tolerance for those — wall time is
  noisy in CI). --absolute adds baseline-free gates: each name=limit
  pair fails when that metric exceeds the limit in the *current*
  document, or is missing from it entirely — these fire even on a cold
  trajectory cache (the disabled-tracing overhead contract rides here).
  esnmf trace-report <run.trace.jsonl> | --admin-port N

  Renders a trace (a --trace file, or the live in-memory ring fetched
  from a factorize --admin-port listener via TRACEDUMP) as a markdown
  report: wall time by span kind, convergence per iteration, sparsity
  per selection pass, and per-worker compute/wait/straggle counters.
  esnmf bench-compare --before baseline.json --after BENCH_smoke.json
                   [--guards wall_s] [--out report.md]

  Prints (and with --out also writes) a before/after markdown table of
  the guarded metrics of two trajectory documents — the report
  scripts/perf_compare.sh and the CI PGO lane publish. Informational
  only: it reports ratios, bench-check gates.
  esnmf help

EXIT CODES:
  0 success · 1 runtime/I-O failure · 2 usage or config error ·
  3 corrupt snapshot/store/wire data · 4 protocol violation between
  coordinator and worker
"#;

fn main() {
    logging::level_from_env();
    let exit = match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            e.exit_code()
        }
    };
    std::process::exit(exit);
}

fn run() -> CliResult {
    let mut args = Args::from_env().map_err(EsnmfError::usage)?;
    if args.flag("verbose") {
        logging::set_level(logging::Level::Debug);
    }
    if args.flag("quiet") {
        logging::set_level(logging::Level::Warn);
    }
    match args.subcommand.clone().as_deref() {
        Some("factorize") => cmd_factorize(&mut args),
        Some("ingest") => cmd_ingest(&mut args),
        Some("experiment") => cmd_experiment(&mut args),
        Some("serve") => cmd_serve(&mut args),
        Some("worker") => cmd_worker(&mut args),
        Some("gen-corpus") => cmd_gen_corpus(&mut args),
        Some("artifacts") => cmd_artifacts(&mut args),
        Some("bench-check") => cmd_bench_check(&mut args),
        Some("bench-compare") => cmd_bench_compare(&mut args),
        Some("trace-report") => cmd_trace_report(&mut args),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => Err(EsnmfError::usage(format!(
            "unknown subcommand {other:?}\n{USAGE}"
        ))),
    }
}

fn build_run_config(args: &mut Args) -> CliResult<RunConfig> {
    let mut cfg = RunConfig::default();
    if let Some(path) = args.opt_str("config") {
        let file = ConfigFile::load(std::path::Path::new(&path)).map_err(EsnmfError::config)?;
        cfg.apply_file(&file)
            .map_err(|e| EsnmfError::config(format!("{e:#}")))?;
    }
    if let Some(v) = args.opt_str("corpus") {
        cfg.corpus = v;
    }
    if let Some(v) = args.opt_str("corpus-store") {
        cfg.corpus_store = Some(v);
    }
    if let Some(v) = args.opt_str("scale") {
        cfg.scale =
            Scale::parse(&v).ok_or_else(|| EsnmfError::usage(format!("bad --scale {v}")))?;
    }
    if let Some(v) = args.opt_parse::<u64>("seed").map_err(EsnmfError::usage)? {
        cfg.seed = v;
    }
    if let Some(v) = args.opt_str("algorithm") {
        cfg.algorithm = match v.as_str() {
            "als" => Algorithm::Als,
            "seq" | "sequential" => Algorithm::Sequential,
            other => return Err(EsnmfError::usage(format!("bad --algorithm {other}"))),
        };
    }
    if let Some(v) = args.opt_str("backend") {
        cfg.backend = BackendKind::parse(&v)
            .ok_or_else(|| EsnmfError::usage(format!("bad --backend {v}")))?;
    }
    if let Some(v) = args.opt_parse::<usize>("k").map_err(EsnmfError::usage)? {
        cfg.k = v;
    }
    if let Some(v) = args.opt_parse::<usize>("iters").map_err(EsnmfError::usage)? {
        cfg.iters = v;
    }
    if let Some(v) = args.opt_parse::<f64>("tol").map_err(EsnmfError::usage)? {
        cfg.tol = v;
    }
    if let Some(v) = args.opt_str("sparsity") {
        cfg.sparsity_mode = v;
    }
    if let Some(v) = args.opt_str("objective") {
        cfg.objective = v;
    }
    if let Some(v) = args.opt_parse::<usize>("t-u").map_err(EsnmfError::usage)? {
        cfg.t_u = Some(v);
    }
    if let Some(v) = args.opt_parse::<usize>("t-v").map_err(EsnmfError::usage)? {
        cfg.t_v = Some(v);
    }
    if let Some(v) = args
        .opt_parse::<usize>("init-nnz")
        .map_err(EsnmfError::usage)?
    {
        cfg.init_nnz = Some(v);
    }
    if let Some(v) = args.opt_parse::<f32>("tau-u").map_err(EsnmfError::usage)? {
        cfg.tau_u = Some(v);
    }
    if let Some(v) = args.opt_parse::<f32>("tau-v").map_err(EsnmfError::usage)? {
        cfg.tau_v = Some(v);
    }
    if let Some(v) = args.opt_threads("threads").map_err(EsnmfError::usage)? {
        cfg.threads = v;
    }
    if let Some(v) = args.opt_threads("block-rows").map_err(EsnmfError::usage)? {
        cfg.block_rows = v;
    }
    if let Some(v) = args.opt_str("save-model") {
        cfg.save_model = Some(v);
    }
    if let Some(v) = args
        .opt_parse::<usize>("checkpoint-every")
        .map_err(EsnmfError::usage)?
    {
        cfg.checkpoint_every = v;
    }
    if let Some(v) = args.opt_str("resume") {
        cfg.resume = Some(v);
    }
    if let Some(v) = args.opt_str("warm-start") {
        cfg.warm_start = Some(v);
    }
    if args.flag("distributed") {
        cfg.distributed = true;
    }
    if let Some(v) = args
        .opt_parse::<usize>("dist-workers")
        .map_err(EsnmfError::usage)?
    {
        cfg.dist_workers = v;
    }
    if let Some(v) = args.opt_str("dist-listen") {
        cfg.dist_listen = v;
    }
    if let Some(v) = args
        .opt_parse::<u64>("dist-timeout")
        .map_err(EsnmfError::usage)?
    {
        cfg.dist_timeout_s = v;
    }
    if let Some(v) = args.opt_str("trace") {
        cfg.trace_path = Some(v);
    }
    Ok(cfg)
}

/// Load a snapshot with path context on the error (the typed
/// [`EsnmfError::Snapshot`] category — and its exit code — survive the
/// wrapping).
fn load_snapshot(path: &str) -> CliResult<esnmf::io::Snapshot> {
    esnmf::io::Snapshot::load(std::path::Path::new(path))
        .map_err(|e| EsnmfError::from(e).context(format!("loading snapshot {path}")))
}

/// Persist the finished factorization as a `.esnmf` snapshot. `used` is
/// the options the run *actually* trained with when they differ from the
/// CLI's (a resumed run takes its solver math from the snapshot, and the
/// saved model must record that, not the flag defaults).
fn save_model(
    path: &str,
    cfg: &RunConfig,
    corpus: &dyn AlsCorpus,
    r: &esnmf::nmf::NmfResult,
    used: Option<&esnmf::nmf::NmfOptions>,
) -> CliResult {
    let options = match used {
        Some(o) => o.clone(),
        None => cfg
            .nmf_options()
            .map_err(|e| EsnmfError::config(format!("{e:#}")))?,
    };
    let snap = esnmf::io::Snapshot {
        options,
        u: r.u.clone(),
        v: r.v.clone(),
        terms: corpus.terms().to_vec(),
        doc_labels: corpus.doc_labels().map(|l| l.to_vec()),
        label_names: corpus.label_names().to_vec(),
        corpus_digest: corpus.digest(),
        progress: esnmf::io::Progress {
            iterations: r.iterations,
            residuals: r.residuals.clone(),
            errors: r.errors.clone(),
            memory: r.memory,
            elapsed_s: r.elapsed_s,
        },
    };
    snap.save(std::path::Path::new(path))
        .map_err(|e| EsnmfError::from(e).context(format!("saving snapshot {path}")))?;
    log_info!("snapshot", "wrote model snapshot to {path}");
    Ok(())
}

fn load_corpus(cfg: &RunConfig) -> CliResult<TermDocMatrix> {
    if let Some(dir) = cfg.corpus.strip_prefix("dir:") {
        return Ok(corpus::loader::load_dir(std::path::Path::new(dir))?);
    }
    let spec = match cfg.corpus.as_str() {
        "reuters" => corpus::reuters_sim(cfg.scale),
        "wikipedia" => corpus::wikipedia_sim(cfg.scale),
        "pubmed" => corpus::pubmed_sim(cfg.scale),
        other => {
            return Err(EsnmfError::config(format!(
                "unknown corpus {other:?} (reuters|wikipedia|pubmed|dir:<path>)"
            )))
        }
    };
    log_info!("corpus", "generating {} at {:?} scale", spec.name, cfg.scale);
    Ok(corpus::generate_tdm(&spec, cfg.seed))
}

/// A corpus ready to factorize: fully resident, or an opened `.estdm`
/// store streamed from disk. Both sides of the enum implement
/// [`AlsCorpus`], so everything downstream of loading is shared.
enum LoadedCorpus {
    Mem(TermDocMatrix),
    Store(CorpusStore),
}

impl LoadedCorpus {
    fn as_als(&self) -> &dyn AlsCorpus {
        match self {
            LoadedCorpus::Mem(tdm) => tdm,
            LoadedCorpus::Store(store) => store,
        }
    }
}

/// `--corpus-store` wins over `--corpus`; everything else loads as before.
fn load_any_corpus(cfg: &RunConfig) -> CliResult<LoadedCorpus> {
    match &cfg.corpus_store {
        Some(path) => {
            let store = CorpusStore::open(std::path::Path::new(path))
                .map_err(|e| EsnmfError::from(e).context(format!("opening corpus store {path}")))?;
            log_info!(
                "corpus",
                "opened store {path}: {} terms × {} docs, nnz {} ({} + {} shards on disk)",
                store.n_terms(),
                store.n_docs(),
                store.nnz(),
                store.terms_major().n_shards(),
                store.docs_major().n_shards(),
            );
            Ok(LoadedCorpus::Store(store))
        }
        None => Ok(LoadedCorpus::Mem(load_corpus(cfg)?)),
    }
}

/// Run the configured factorization. The second return is the options
/// the run actually trained with when they differ from the CLI's (a
/// resumed run takes its solver math from the snapshot) — `--save-model`
/// must record those.
fn run_factorization(
    cfg: &RunConfig,
    loaded: &LoadedCorpus,
) -> CliResult<(esnmf::nmf::NmfResult, Option<esnmf::nmf::NmfOptions>)> {
    let out = run_factorization_inner(cfg, loaded)?;
    // a store fault latched mid-run means the "result" was computed on
    // partial data: surface the typed error instead of reporting it as
    // clean (the run loop already checkpointed the last-good state when
    // --checkpoint-every was on)
    if let LoadedCorpus::Store(store) = loaded {
        if let Some(e) = store.take_error() {
            return Err(EsnmfError::from(e).context(format!(
                "corpus store {} turned unreadable mid-run \
                 (a checkpointed last-good state survives if --checkpoint-every was set)",
                store.path().display()
            )));
        }
    }
    Ok(out)
}

fn run_factorization_inner(
    cfg: &RunConfig,
    loaded: &LoadedCorpus,
) -> CliResult<(esnmf::nmf::NmfResult, Option<esnmf::nmf::NmfOptions>)> {
    let corpus = loaded.as_als();
    if matches!(loaded, LoadedCorpus::Store(_)) && cfg.backend != BackendKind::Native {
        return Err(EsnmfError::config(
            "--corpus-store requires --backend native (the XLA backend needs the matrix resident)",
        ));
    }
    if cfg.distributed {
        // the coordinator side of `esnmf worker`: same blocked ALS, with
        // half-step spans scattered to remote workers over the shared store
        let store = match loaded {
            LoadedCorpus::Store(store) => store,
            LoadedCorpus::Mem(_) => {
                return Err(EsnmfError::config(
                    "--distributed requires --corpus-store <c.estdm> \
                     (workers must open the same on-disk corpus; see `esnmf ingest`)",
                ))
            }
        };
        if cfg.algorithm != Algorithm::Als {
            return Err(EsnmfError::config(
                "--distributed requires --algorithm als",
            ));
        }
        if cfg.resume.is_some() || cfg.warm_start.is_some() {
            return Err(EsnmfError::config(
                "--distributed does not combine with --resume/--warm-start",
            ));
        }
        let opts = cfg
            .nmf_options()
            .map_err(|e| EsnmfError::config(format!("{e:#}")))?;
        let r = esnmf::coordinator::run_distributed(store, &opts, &cfg.dist_options())?;
        return Ok((r, None));
    }
    // checkpoint continuation / warm start run on the native ALS driver
    if cfg.resume.is_some() || cfg.warm_start.is_some() {
        if cfg.resume.is_some() && cfg.warm_start.is_some() {
            return Err(EsnmfError::config(
                "--resume and --warm-start are mutually exclusive \
                 (resume continues the exact run; warm-start begins a new one)",
            ));
        }
        if cfg.algorithm != Algorithm::Als || cfg.backend != BackendKind::Native {
            return Err(EsnmfError::config(
                "--resume/--warm-start require --algorithm als --backend native",
            ));
        }
        let opts = cfg
            .nmf_options()
            .map_err(|e| EsnmfError::config(format!("{e:#}")))?;
        if let Some(path) = &cfg.resume {
            let snap = load_snapshot(path)?;
            log_info!(
                "snapshot",
                "resuming from {path} at iteration {}",
                snap.progress.iterations
            );
            let used = esnmf::nmf::resume_options(&opts, &snap);
            let r = esnmf::nmf::resume_corpus(corpus, &opts, &snap)?;
            return Ok((r, Some(used)));
        }
        let path = cfg.warm_start.as_ref().unwrap();
        let snap = load_snapshot(path)?;
        snap.check_k(opts.k)
            .map_err(|e| EsnmfError::from(e).context("warm start"))?;
        let u0 = esnmf::nmf::init::warm_start_u(
            &snap.u,
            &snap.terms,
            corpus.terms(),
            opts.k,
            opts.seed,
        );
        let old: std::collections::HashSet<&str> =
            snap.terms.iter().map(|t| t.as_str()).collect();
        let carried = corpus
            .terms()
            .iter()
            .filter(|t| old.contains(t.as_str()))
            .count();
        log_info!(
            "snapshot",
            "warm start from {path}: {carried}/{} terms carried over",
            corpus.n_terms()
        );
        return Ok((
            esnmf::nmf::factorize_from_corpus(corpus, &opts, u0),
            None,
        ));
    }
    match cfg.algorithm {
        Algorithm::Sequential => Ok((
            factorize_sequential_corpus(corpus, &cfg.sequential_options()),
            None,
        )),
        Algorithm::Als => {
            let opts = cfg
                .nmf_options()
                .map_err(|e| EsnmfError::config(format!("{e:#}")))?;
            let r = match (cfg.backend, loaded) {
                (BackendKind::Native, LoadedCorpus::Mem(tdm)) => {
                    NativeBackend::new().factorize(tdm, &opts)
                }
                (BackendKind::Native, LoadedCorpus::Store(store)) => {
                    Ok(esnmf::nmf::factorize_corpus(store, &opts))
                }
                (BackendKind::Xla, LoadedCorpus::Store(_)) => {
                    unreachable!("store runs are rejected above for the XLA backend")
                }
                (BackendKind::Xla, LoadedCorpus::Mem(tdm)) => {
                    let dir = runtime::artifact_dir();
                    let guard = XlaExecutor::spawn(dir)?;
                    let manifest_fit = {
                        // pick the smallest artifact that contains the corpus
                        let engine_manifest =
                            esnmf::runtime::Manifest::load(&runtime::artifact_dir())?;
                        engine_manifest
                            .best_fit(
                                ProgramKind::AlsIter,
                                tdm.n_terms(),
                                tdm.n_docs(),
                                opts.k,
                            )
                            .map(|p| (p.n, p.m, p.k))
                            .ok_or_else(|| {
                                anyhow::anyhow!(
                                    "no artifact fits ({} terms, {} docs, k={}); re-run `make artifacts`",
                                    tdm.n_terms(),
                                    tdm.n_docs(),
                                    opts.k
                                )
                            })?
                    };
                    let (n, m, k) = manifest_fit;
                    log_info!("backend", "xla artifact shape ({n}, {m}, {k})");
                    XlaBackend::new(guard.handle.clone(), n, m, k).factorize(tdm, &opts)
                }
            };
            Ok((r?, None))
        }
    }
}

fn cmd_factorize(args: &mut Args) -> CliResult {
    let mut cfg = build_run_config(args)?;
    let top = args.parse_or("top", 5usize).map_err(EsnmfError::usage)?;
    if let Some(v) = args
        .opt_parse::<u16>("admin-port")
        .map_err(EsnmfError::usage)?
    {
        cfg.admin_port = Some(v);
    }
    args.check_unknown().map_err(EsnmfError::usage)?;
    // fail fast on an unknown objective or an incoherent pairing
    // (kl + sequential/xla) before any corpus work happens
    cfg.objective()
        .map_err(|e| EsnmfError::config(format!("{e:#}")))?;
    if cfg.tracing() {
        let sink = cfg.trace_path.as_deref().map(std::path::Path::new);
        esnmf::util::trace::enable(sink).map_err(|e| {
            EsnmfError::Io(e).context(format!(
                "opening trace sink {}",
                cfg.trace_path.as_deref().unwrap_or("<ring only>")
            ))
        })?;
    }

    let loaded = load_any_corpus(&cfg)?;
    // kept alive for the life of the run (the Drop stops its thread)
    let _admin = match cfg.admin_port {
        Some(port) => {
            let resident = match &loaded {
                LoadedCorpus::Store(store) => Some(store.resident_shared()),
                LoadedCorpus::Mem(_) => None,
            };
            let surface: Arc<dyn AdminSurface> = Arc::new(FactorizeAdmin::new(resident));
            let admin = AdminServer::start_on(&format!("127.0.0.1:{port}"), surface)?;
            println!(
                "admin listener on {} (HEALTH METRICS PROGRESS TRACEDUMP)",
                admin.addr()
            );
            Some(admin)
        }
        None => None,
    };
    let corpus = loaded.as_als();
    let (n_terms, n_docs, a_nnz) = (corpus.n_terms(), corpus.n_docs(), corpus.a_rows().nnz());
    log_info!(
        "factorize",
        "{n_terms} terms × {n_docs} docs, nnz(A) = {a_nnz} ({:.2}% sparse)",
        esnmf::eval::sparsity_fraction(n_terms, n_docs, a_nnz) * 100.0
    );
    let run = run_factorization(&cfg, &loaded);
    // flush and close the JSONL sink whether the run succeeded or not —
    // a partial trace of a failed run is exactly when you want one
    if cfg.tracing() {
        esnmf::util::trace::disable();
        if let Some(path) = &cfg.trace_path {
            println!("trace written to {path}");
        }
    }
    let (r, used_opts) = run?;
    let corpus = loaded.as_als();
    if let Some(path) = &cfg.save_model {
        save_model(path, &cfg, corpus, &r, used_opts.as_ref())?;
        println!("saved model snapshot to {path}");
    }

    println!(
        "completed {} iterations in {:.3}s  final residual {:.3e}  final error {:.4}",
        r.iterations,
        r.elapsed_s,
        r.final_residual(),
        r.final_error()
    );
    println!(
        "nnz(U) = {}  nnz(V) = {}  peak stored = {}",
        r.u.nnz(),
        r.v.nnz(),
        r.memory.max_combined_nnz
    );
    // a resumed run trains under the snapshot's objective, not the flags'
    let objective = match &used_opts {
        Some(o) => o.objective,
        None => cfg
            .objective()
            .map_err(|e| EsnmfError::config(format!("{e:#}")))?,
    };
    // one greppable line pinning the full bit-level outcome — the CI
    // distributed-smoke job diffs this between single-process and
    // N-worker runs
    println!(
        "factors digest: {:#018x}  objective={}",
        r.digest(),
        objective.name()
    );
    if let LoadedCorpus::Store(store) = &loaded {
        println!(
            "resident corpus peak = {} bytes ({} on disk)",
            store.resident().peak(),
            store.payload_bytes()
        );
    }
    let dataset = cfg
        .corpus_store
        .clone()
        .unwrap_or_else(|| cfg.corpus.clone());
    match &loaded {
        // in-memory: the full Fig. 1 report, U·Vᵀ support included
        LoadedCorpus::Mem(_) => print!(
            "{}",
            SparsityReport::from_parts(n_terms, n_docs, a_nnz, &r.u, &r.v).format(&dataset)
        ),
        // out-of-core: skip the U·Vᵀ product — its structural support can
        // approach dense n×m, the very memory the store run avoided
        LoadedCorpus::Store(_) => print!(
            "{}",
            SparsityReport::format_factors_only(&dataset, n_terms, n_docs, a_nnz, &r.u, &r.v)
        ),
    }
    println!("\nTop {top} terms per topic:");
    print!(
        "{}",
        format_topic_table(&topic_term_table(&r.u, corpus.terms(), top), cfg.k)
    );
    if let Some(labels) = corpus.doc_labels() {
        let acc = mean_topic_accuracy(&r.v, labels, corpus.label_names().len());
        println!("\nmean clustering accuracy (Eq. 3.3): {acc:.4}");
    }
    // the objective-agnostic predictive measure: every stride-th document
    // re-folded against the frozen U and scored under the implied unigram
    let h = esnmf::eval::heldout_mean_log_likelihood(
        corpus.a_cols(),
        &r.u,
        objective,
        cfg.foldin_budget(),
        esnmf::sparse::TieMode::KeepTies,
    );
    println!(
        "held-out mean log-likelihood: {:.4}  ({} docs, {} tokens)",
        h.mean_log_likelihood, h.docs, h.tokens
    );
    Ok(())
}

/// `esnmf ingest`: build the corpus (preset generator or `dir:` loader)
/// and write it to an `.estdm` store for out-of-core factorization.
fn cmd_ingest(args: &mut Args) -> CliResult {
    let cfg = build_run_config(args)?;
    let out = args
        .opt_str("out")
        .ok_or_else(|| EsnmfError::usage("--out <corpus.estdm> required"))?;
    let shard_rows = args
        .opt_threads("shard-rows")
        .map_err(EsnmfError::usage)?
        .unwrap_or(0);
    args.check_unknown().map_err(EsnmfError::usage)?;
    if cfg.corpus_store.is_some() {
        return Err(EsnmfError::config(
            "ingest reads a corpus (--corpus/dir:), not a store",
        ));
    }

    let tdm = load_corpus(&cfg)?;
    let path = std::path::Path::new(&out);
    CorpusStore::write(path, &tdm, shard_rows)
        .map_err(|e| EsnmfError::from(e).context(format!("writing corpus store {out}")))?;
    // reopen + verify: an ingest that cannot be read back is not an ingest
    let store = CorpusStore::open(path)
        .map_err(|e| EsnmfError::from(e).context(format!("reopening corpus store {out}")))?;
    store
        .verify()
        .map_err(|e| EsnmfError::from(e).context(format!("verifying corpus store {out}")))?;
    println!(
        "wrote {out}: {} terms × {} docs, nnz {}, digest {:#018x}, {} + {} shards ({} bytes on disk)",
        store.n_terms(),
        store.n_docs(),
        store.nnz(),
        store.digest(),
        store.terms_major().n_shards(),
        store.docs_major().n_shards(),
        store.payload_bytes(),
    );
    Ok(())
}

/// `esnmf bench-check`: the CI memory-regression gate over two merged
/// `BENCH_smoke.json` trajectory points.
fn cmd_bench_check(args: &mut Args) -> CliResult {
    let previous = args
        .opt_str("previous")
        .ok_or_else(|| EsnmfError::usage("--previous <prev.json> required"))?;
    let current = args
        .opt_str("current")
        .ok_or_else(|| EsnmfError::usage("--current <BENCH_smoke.json> required"))?;
    let tolerance = args
        .parse_or("tolerance", 1.10f64)
        .map_err(EsnmfError::usage)?;
    let guards = args.str_or("guards", "max_intermediate_nnz,resident_corpus,p99_us");
    // baseline-free limits: `name=limit[,name=limit...]`, gated against
    // the current document alone (they fire even on a cold cache)
    let absolute: Vec<(String, f64)> = match args.opt_str("absolute") {
        None => Vec::new(),
        Some(spec) => spec
            .split(',')
            .map(str::trim)
            .filter(|p| !p.is_empty())
            .map(|pair| {
                let (name, limit) = pair.split_once('=').ok_or_else(|| {
                    EsnmfError::usage(format!("bad --absolute entry {pair:?} (want name=limit)"))
                })?;
                let limit: f64 = limit.parse().map_err(|_| {
                    EsnmfError::usage(format!("bad --absolute limit in {pair:?}"))
                })?;
                Ok((name.trim().to_string(), limit))
            })
            .collect::<CliResult<_>>()?,
    };
    args.check_unknown().map_err(EsnmfError::usage)?;

    let cur = std::fs::read_to_string(&current)
        .map_err(|e| {
            EsnmfError::Other(format!(
                "bench-check: cannot read current trajectory {current}: {e}"
            ))
        })
        .and_then(|text| {
            esnmf::util::json::Json::parse(&text).map_err(|e| {
                EsnmfError::Other(format!(
                    "bench-check: current trajectory {current} is corrupt: {e}"
                ))
            })
        })?;
    let violations = esnmf::util::bench::absolute_violations(&cur, &absolute);
    for v in &violations {
        eprintln!("bench-check: ABSOLUTE {v}");
    }
    if !absolute.is_empty() && violations.is_empty() {
        println!(
            "bench-check: {} absolute limit(s) hold in the current trajectory",
            absolute.len()
        );
    }
    // only a genuinely *absent* baseline passes (first run, cold cache);
    // a baseline that exists but cannot be read or parsed must fail
    // loudly — swallowing it would silently disable the regression gate
    let prev = match std::fs::read_to_string(&previous) {
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            println!(
                "bench-check: no previous trajectory point at {previous}; nothing to compare"
            );
            None
        }
        Err(e) => {
            return Err(EsnmfError::Other(format!(
                "bench-check: cannot read previous trajectory {previous}: {e}"
            )))
        }
        Ok(text) => Some(esnmf::util::json::Json::parse(&text).map_err(|e| {
            EsnmfError::Other(format!(
                "bench-check: previous trajectory {previous} is corrupt: {e}"
            ))
        })?),
    };
    // the committed seed trajectory is `{"suites": {}}` — a baseline
    // with nothing recorded yet. The first gated run establishes the
    // baseline: record and pass, explicitly, rather than letting the
    // comparison succeed vacuously over zero shared metrics
    let prev = match prev {
        Some(p) if esnmf::util::bench::trajectory_is_empty(&p) => {
            println!(
                "bench-check: previous trajectory {previous} has no recorded suites; \
                 {current} becomes the baseline (record and pass)"
            );
            None
        }
        other => other,
    };
    let mut regressed = 0usize;
    if let Some(prev) = prev {
        let guard_list: Vec<&str> = guards
            .split(',')
            .map(str::trim)
            .filter(|g| !g.is_empty())
            .collect();
        let regressions =
            esnmf::util::bench::metric_regressions(&prev, &cur, &guard_list, tolerance);
        for r in &regressions {
            eprintln!(
                "bench-check: REGRESSION {}: {} -> {} (> {tolerance}x)",
                r.path, r.previous, r.current
            );
        }
        if regressions.is_empty() {
            println!(
                "bench-check: guarded metrics within {tolerance}x of the previous trajectory point"
            );
        }
        regressed = regressions.len();
    }
    if regressed == 0 && violations.is_empty() {
        return Ok(());
    }
    Err(EsnmfError::Other(format!(
        "{} guarded metric(s) regressed, {} absolute limit(s) violated",
        regressed,
        violations.len()
    )))
}

/// `esnmf trace-report`: render a trace (a `--trace` JSONL file, or the
/// live ring fetched from a `factorize --admin-port` listener) as a
/// markdown per-phase time/convergence/sparsity breakdown.
fn cmd_trace_report(args: &mut Args) -> CliResult {
    let admin_port = args
        .opt_parse::<u16>("admin-port")
        .map_err(EsnmfError::usage)?;
    let file = args.positional.first().cloned();
    args.check_unknown().map_err(EsnmfError::usage)?;
    let text = match (file, admin_port) {
        (Some(path), None) => std::fs::read_to_string(&path)
            .map_err(|e| EsnmfError::Io(e).context(format!("reading trace {path}")))?,
        (None, Some(port)) => fetch_trace_dump(port)?,
        (Some(_), Some(_)) => {
            return Err(EsnmfError::usage(
                "trace-report takes a trace file OR --admin-port, not both",
            ))
        }
        (None, None) => {
            return Err(EsnmfError::usage(
                "trace-report needs <run.trace.jsonl> or --admin-port N",
            ))
        }
    };
    let events = esnmf::util::trace::parse_trace(&text)
        .map_err(|e| EsnmfError::Other(format!("trace-report: {e}")))?;
    print!("{}", esnmf::util::trace::render_report(&events));
    Ok(())
}

/// Fetch the in-memory trace ring from a live admin listener: one
/// `TRACEDUMP` command, body read until its `# EOF` terminator.
fn fetch_trace_dump(port: u16) -> CliResult<String> {
    use std::io::{BufRead, BufReader, Write};
    let addr = format!("127.0.0.1:{port}");
    let mut stream = std::net::TcpStream::connect(&addr)
        .map_err(|e| EsnmfError::Io(e).context(format!("connecting to admin listener {addr}")))?;
    stream.write_all(b"TRACEDUMP\n")?;
    let mut out = String::new();
    for line in BufReader::new(stream).lines() {
        let line = line?;
        if line.trim() == "# EOF" {
            return Ok(out);
        }
        out.push_str(&line);
        out.push('\n');
    }
    Err(EsnmfError::protocol(format!(
        "admin listener {addr} closed the TRACEDUMP stream before its # EOF terminator"
    )))
}

/// Before/after markdown report over two trajectory documents. Purely
/// informational — the PGO lane publishes this next to the gated
/// `bench-check` so a human can see *how much* moved, not just whether
/// the gate tripped.
fn cmd_bench_compare(args: &mut Args) -> CliResult {
    let before = args
        .opt_str("before")
        .ok_or_else(|| EsnmfError::usage(format!("--before required\n{USAGE}")))?;
    let after = args
        .opt_str("after")
        .ok_or_else(|| EsnmfError::usage(format!("--after required\n{USAGE}")))?;
    let guards = args.str_or("guards", "wall_s");
    let out = args.opt_str("out");
    args.check_unknown().map_err(EsnmfError::usage)?;

    let read = |path: &str| -> Result<esnmf::util::json::Json, EsnmfError> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            EsnmfError::Other(format!("bench-compare: cannot read trajectory {path}: {e}"))
        })?;
        esnmf::util::json::Json::parse(&text).map_err(|e| {
            EsnmfError::Other(format!("bench-compare: trajectory {path} is corrupt: {e}"))
        })
    };
    let before_doc = read(&before)?;
    let after_doc = read(&after)?;
    let guard_list: Vec<&str> = guards.split(',').map(str::trim).filter(|g| !g.is_empty()).collect();
    let md = esnmf::util::bench::markdown_compare(&before_doc, &after_doc, &guard_list);
    print!("{md}");
    if let Some(path) = out {
        std::fs::write(&path, &md).map_err(|e| {
            EsnmfError::Other(format!("bench-compare: cannot write report {path}: {e}"))
        })?;
        println!("bench-compare: report written to {path}");
    }
    Ok(())
}

fn cmd_experiment(args: &mut Args) -> CliResult {
    let id = args
        .positional
        .first()
        .cloned()
        .ok_or_else(|| EsnmfError::usage(format!("experiment id required\n{USAGE}")))?;
    let scale = Scale::parse(&args.str_or("scale", "small"))
        .ok_or_else(|| EsnmfError::usage("bad --scale"))?;
    let seed = args.parse_or("seed", 42u64).map_err(EsnmfError::usage)?;
    let fast = args.flag("fast");
    let out_dir = args.opt_str("out");
    args.check_unknown().map_err(EsnmfError::usage)?;

    let cfg = ExpConfig { scale, seed, fast };
    let ids: Vec<&str> = if id == "all" {
        experiments::ALL.to_vec()
    } else {
        vec![id.as_str()]
    };
    for id in ids {
        log_info!("experiment", "running {id}");
        let result = experiments::run(id, &cfg)?;
        if let Some(dir) = &out_dir {
            std::fs::create_dir_all(dir)?;
            let path = std::path::Path::new(dir).join(format!("{id}.json"));
            std::fs::write(&path, result.to_string())?;
            log_info!("experiment", "wrote {}", path.display());
        }
    }
    Ok(())
}

fn cmd_serve(args: &mut Args) -> CliResult {
    let addr = args.str_or("addr", "127.0.0.1:7878");
    // flags the snapshot path must cross-check (option reads don't
    // consume the value, so build_run_config still sees them)
    let explicit_k = args.opt_parse::<usize>("k").map_err(EsnmfError::usage)?;
    let explicit_corpus = args.opt_str("corpus");
    let explicit_store = args.opt_str("corpus-store");
    let mut cfg = build_run_config(args)?;
    if let Some(v) = args
        .opt_threads("serve-threads")
        .map_err(EsnmfError::usage)?
    {
        cfg.serve_threads = v;
    }
    if let Some(v) = args
        .opt_parse::<usize>("cache-size")
        .map_err(EsnmfError::usage)?
    {
        cfg.serve_cache = v;
    }
    if let Some(v) = args
        .opt_parse::<usize>("foldin-t")
        .map_err(EsnmfError::usage)?
    {
        cfg.foldin_t = Some(v);
    }
    if let Some(v) = args.opt_str("model") {
        cfg.model = Some(v);
    }
    if let Some(v) = args
        .opt_parse::<u16>("admin-port")
        .map_err(EsnmfError::usage)?
    {
        cfg.admin_port = Some(v);
    }
    if args.flag("watch-model") {
        cfg.watch_model = true;
    }
    args.check_unknown().map_err(EsnmfError::usage)?;
    if cfg.watch_model && cfg.model.is_none() {
        return Err(EsnmfError::config(
            "--watch-model requires --model <path.esnmf> (a file to watch)",
        ));
    }

    let (model, provenance) = match cfg.model.clone() {
        Some(path) => {
            // cold start from disk: no corpus generation, no
            // factorization; one read yields both the snapshot and the
            // file CRC recorded in PROVENANCE
            let (snap, file_crc) = esnmf::io::Snapshot::load_with_crc(std::path::Path::new(&path))
                .map_err(|e| EsnmfError::from(e).context(format!("loading snapshot {path}")))?;
            if let Some(k) = explicit_k {
                snap.check_k(k)
                    .map_err(|e| EsnmfError::from(e).context("serve --model"))?;
            }
            if explicit_store.is_some() {
                // an explicit store alongside --model verifies the
                // snapshot belongs to that corpus — from the store's
                // metadata digest, without materializing the matrix
                let store = match load_any_corpus(&cfg)? {
                    LoadedCorpus::Store(s) => s,
                    LoadedCorpus::Mem(_) => unreachable!("corpus_store is set"),
                };
                snap.check_digest(store.digest(), store.n_terms(), store.n_docs())
                    .map_err(|e| EsnmfError::from(e).context("serve --model"))?;
            } else if explicit_corpus.is_some() {
                // an explicit corpus alongside --model is a request to
                // verify the snapshot actually belongs to that corpus
                let tdm = load_corpus(&cfg)?;
                snap.check_corpus(&tdm)
                    .map_err(|e| EsnmfError::from(e).context("serve --model"))?;
            }
            log_info!(
                "serve",
                "loaded snapshot {path}: {} terms × {} docs, k={}",
                snap.u.rows,
                snap.v.rows,
                snap.options.k
            );
            let provenance = Provenance::from_snapshot(&snap, Some(&path), Some(file_crc));
            // from_snapshot already defaults the fold-in budget to the
            // snapshot's t_v; only an explicit --foldin-t overrides it
            let mut model = TopicModel::from_snapshot(snap);
            if cfg.foldin_t.is_some() {
                model = model.with_foldin_budget(cfg.foldin_t);
            }
            (Arc::new(model), provenance)
        }
        None => {
            let loaded = load_any_corpus(&cfg)?;
            let (r, used_opts) = run_factorization(&cfg, &loaded)?;
            let corpus = loaded.as_als();
            if let Some(path) = &cfg.save_model {
                save_model(path, &cfg, corpus, &r, used_opts.as_ref())?;
            }
            let digest = corpus.digest();
            let trained = used_opts.or_else(|| cfg.nmf_options().ok());
            // fold-ins answer under the objective the model was trained
            // with, exactly as the snapshot-serving path does
            let objective = trained
                .as_ref()
                .map(|o| o.objective)
                .unwrap_or(esnmf::nmf::ObjectiveKind::Frobenius);
            let model = Arc::new(
                TopicModel::new(r.u, r.v, corpus.terms().to_vec())
                    .with_foldin_budget(cfg.foldin_budget())
                    .with_objective(objective),
            );
            let mut provenance = Provenance::from_model(&model);
            provenance.corpus_digest = Some(digest);
            if let Some(o) = &trained {
                provenance.sparsity = esnmf::coordinator::model::sparsity_label(&o.sparsity);
                provenance.options = esnmf::coordinator::model::options_label(o);
            }
            (model, provenance)
        }
    };
    let metrics = MetricsRegistry::new();
    let opts = cfg.serve_options();
    let workers = opts.threads;
    let cache = opts.cache_size;
    let state = Arc::new(ServerState::new(model, metrics, cache).with_provenance(provenance));
    let server = TopicServer::serve_state(&addr, Arc::clone(&state), workers)?;
    // kept alive for the life of the process (the Drop stops its thread)
    let _admin = match cfg.admin_port {
        Some(port) => {
            let admin = AdminServer::start(&format!("127.0.0.1:{port}"), Arc::clone(&state))?;
            println!(
                "admin listener on {} (HEALTH READY METRICS PROVENANCE RELOAD)",
                admin.addr()
            );
            Some(admin)
        }
        None => None,
    };
    if cfg.watch_model {
        let path = cfg.model.clone().expect("checked above");
        watch_model(
            Arc::clone(&state),
            std::path::PathBuf::from(path),
            std::time::Duration::from_secs(2),
        );
        println!("watching the model file; edits hot-swap without dropping connections");
    }
    println!(
        "serving topic queries on {} ({workers} connection workers, cache {cache} entries; QUIT closes a session, Ctrl-C stops)",
        server.addr()
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// `esnmf worker`: the stateless compute side of distributed
/// factorization — open the shared `.estdm`, join the coordinator, and
/// serve half-step span requests until shut down.
fn cmd_worker(args: &mut Args) -> CliResult {
    let store = match args
        .positional
        .first()
        .cloned()
        .or_else(|| args.opt_str("store"))
    {
        Some(s) => s,
        None => {
            return Err(EsnmfError::usage(
                "worker needs the shared corpus store: \
                 esnmf worker <corpus.estdm> --coordinator <host:port>",
            ))
        }
    };
    let coordinator = args.str_or("coordinator", "127.0.0.1:7611");
    let objective = match args.opt_str("objective") {
        Some(v) => esnmf::nmf::ObjectiveKind::parse(&v)
            .ok_or_else(|| EsnmfError::usage(format!("bad --objective {v} (frobenius|kl)")))?,
        None => esnmf::nmf::ObjectiveKind::Frobenius,
    };
    let threads = args
        .opt_threads("threads")
        .map_err(EsnmfError::usage)?
        .unwrap_or(0);
    args.check_unknown().map_err(EsnmfError::usage)?;
    let threads = if threads == 0 {
        esnmf::coordinator::default_threads()
    } else {
        threads
    };
    esnmf::coordinator::run_worker(
        std::path::Path::new(&store),
        &coordinator,
        objective,
        threads,
    )
}

fn cmd_gen_corpus(args: &mut Args) -> CliResult {
    let cfg = build_run_config(args)?;
    let out = args
        .opt_str("out")
        .ok_or_else(|| EsnmfError::usage("--out <dir> required"))?;
    args.check_unknown().map_err(EsnmfError::usage)?;
    let spec = match cfg.corpus.as_str() {
        "reuters" => corpus::reuters_sim(cfg.scale),
        "wikipedia" => corpus::wikipedia_sim(cfg.scale),
        "pubmed" => corpus::pubmed_sim(cfg.scale),
        other => {
            return Err(EsnmfError::config(format!(
                "unknown corpus preset {other:?}"
            )))
        }
    };
    let docs = corpus::generate(&spec, cfg.seed);
    let base = std::path::Path::new(&out);
    for (i, doc) in docs.iter().enumerate() {
        let label = &spec.topics[doc.label as usize].name;
        let dir = base.join(label);
        std::fs::create_dir_all(&dir)?;
        std::fs::write(dir.join(format!("doc{i:06}.txt")), doc.tokens.join(" "))?;
    }
    println!("wrote {} documents under {}", docs.len(), base.display());
    Ok(())
}

fn cmd_artifacts(args: &mut Args) -> CliResult {
    let dir = args
        .opt_str("dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(runtime::artifact_dir);
    args.check_unknown().map_err(EsnmfError::usage)?;
    let manifest = esnmf::runtime::Manifest::load(&dir)?;
    println!("artifact dir: {}", dir.display());
    for p in &manifest.programs {
        println!(
            "  {:<28} kind={:?} shape=({}, {}, {}) file={}",
            p.name,
            p.kind,
            p.n,
            p.m,
            p.k,
            p.file.file_name().unwrap_or_default().to_string_lossy()
        );
    }
    let guard = XlaExecutor::spawn(dir)?;
    println!("platform: {}", guard.handle.platform()?);
    let compiled = guard.handle.warmup()?;
    println!("compiled {compiled} programs OK");
    Ok(())
}
