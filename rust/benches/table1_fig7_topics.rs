//! Bench for Table 1 + Fig. 7: global vs column-wise vs sequential topic
//! generation on wikipedia-sim.

mod common;

use esnmf::nmf::{
    factorize, factorize_sequential, NmfOptions, SequentialOptions, SparsityMode,
};
use esnmf::util::bench::BenchSuite;

fn main() {
    let cfg = common::print_paper_rows("table1");
    esnmf::experiments::run("fig7", &cfg).expect("fig7");
    let tdm = common::corpus("wikipedia", &cfg);
    let iters = cfg.iters(50);
    let mut suite = BenchSuite::new("table1/fig7: topic generation variants");
    let global = NmfOptions::new(5)
        .with_iters(iters)
        .with_seed(cfg.seed)
        .with_sparsity(SparsityMode::u_only(50))
        .with_track_error(false);
    suite.bench("global top-50 U", || factorize(&tdm, &global));
    let colwise = NmfOptions::new(5)
        .with_iters(iters)
        .with_seed(cfg.seed)
        .with_sparsity(SparsityMode::PerColumn {
            t_u_col: Some(10),
            t_v_col: None,
        })
        .with_track_error(false);
    suite.bench("column-wise 10/topic", || factorize(&tdm, &colwise));
    let seq = SequentialOptions::new(5, cfg.iters(20))
        .with_budgets(10, tdm.n_docs())
        .with_seed(cfg.seed);
    suite.bench("sequential 10/topic", || factorize_sequential(&tdm, &seq));
}
