//! Serving-plane load test: concurrent CLASSIFY / FOLDIN / BATCH
//! clients hammer a live [`TopicServer`] across a mid-run atomic hot
//! model swap, and the suite records per-command-class p50/p99 latency
//! and overall throughput as guarded trajectory metrics (`p99_us` is in
//! the default `esnmf bench-check` guard list, so a latency regression
//! on the request path fails CI the same way a memory regression does).

use esnmf::coordinator::{MetricsRegistry, ServerState, TopicServer};
use esnmf::io::{Progress, Snapshot};
use esnmf::nmf::NmfOptions;
use esnmf::sparse::Csr;
use esnmf::util::bench::BenchSuite;
use esnmf::util::stats;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Barrier, Mutex};
use std::time::Instant;

const CLIENTS: usize = 4;
const PER_CLIENT: usize = 50;
/// classify + foldin + batch round trips per client iteration
const ROUND_TRIPS_PER_ITER: usize = 3;

fn terms() -> Vec<String> {
    vec![
        "coffee".into(),
        "crop".into(),
        "electrons".into(),
        "atoms".into(),
    ]
}

fn model_a() -> Arc<esnmf::coordinator::TopicModel> {
    let u = Csr::from_dense(4, 2, &[
        0.9, 0.0, //
        0.5, 0.0, //
        0.0, 0.8, //
        0.0, 0.3,
    ]);
    let v = Csr::from_dense(3, 2, &[1.0, 0.0, 0.0, 0.9, 0.4, 0.0]);
    Arc::new(esnmf::coordinator::TopicModel::new(u, v, terms()))
}

/// The same vocabulary with the topic columns exchanged — a visibly
/// different model for the mid-run swap.
fn model_b_snapshot() -> Snapshot {
    let u = Csr::from_dense(4, 2, &[
        0.0, 0.9, //
        0.0, 0.5, //
        0.8, 0.0, //
        0.3, 0.0,
    ]);
    let v = Csr::from_dense(3, 2, &[0.0, 1.0, 0.9, 0.0, 0.0, 0.4]);
    snapshot(u, v)
}

fn model_a_snapshot() -> Snapshot {
    let m = model_a();
    snapshot(m.u.clone(), m.v.clone())
}

fn snapshot(u: Csr, v: Csr) -> Snapshot {
    Snapshot {
        options: NmfOptions::new(2),
        u,
        v,
        terms: terms(),
        doc_labels: None,
        label_names: vec![],
        corpus_digest: 0xBEEF,
        progress: Progress::default(),
    }
}

/// One client: a scripted CLASSIFY / FOLDIN / BATCH mix, per-class
/// latencies in µs appended to the shared accumulators.
fn run_client(
    addr: std::net::SocketAddr,
    barrier: Arc<Barrier>,
    classify_us: Arc<Mutex<Vec<f64>>>,
    foldin_us: Arc<Mutex<Vec<f64>>>,
    batch_us: Arc<Mutex<Vec<f64>>>,
) {
    let stream = TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut writer = stream;
    let mut line = String::new();
    let mut roundtrip = |req: &str, responses: usize| -> f64 {
        let t = Instant::now();
        writer.write_all(req.as_bytes()).expect("write");
        for _ in 0..responses {
            line.clear();
            reader.read_line(&mut line).expect("read");
            assert!(line.starts_with("OK"), "server answered {line:?} to {req:?}");
        }
        t.elapsed().as_secs_f64() * 1e6
    };
    let (mut c, mut f, mut b) = (Vec::new(), Vec::new(), Vec::new());
    barrier.wait(); // start together
    for i in 0..PER_CLIENT {
        if i == PER_CLIENT / 2 {
            barrier.wait(); // the main thread swaps the model here
        }
        let word = ["coffee", "crop", "electrons", "atoms"][i % 4];
        c.push(roundtrip(&format!("CLASSIFY {word} coffee\n"), 1));
        f.push(roundtrip(&format!("FOLDIN {word}:{} crop:1\n", 1 + i % 5), 1));
        b.push(roundtrip(
            &format!("BATCH 2\nTOPICS\nCLASSIFY {word}\n"),
            3, // header + two responses
        ));
    }
    classify_us.lock().unwrap().extend_from_slice(&c);
    foldin_us.lock().unwrap().extend_from_slice(&f);
    batch_us.lock().unwrap().extend_from_slice(&b);
}

fn main() {
    let dir = std::env::temp_dir().join(format!("esnmf_bench_serve_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    let snap_a = dir.join("a.esnmf");
    let snap_b = dir.join("b.esnmf");
    model_a_snapshot().save(&snap_a).expect("save a");
    model_b_snapshot().save(&snap_b).expect("save b");

    let state = Arc::new(ServerState::new(model_a(), MetricsRegistry::new(), 256));
    let server =
        TopicServer::serve_state("127.0.0.1:0", Arc::clone(&state), 8).expect("server");
    let addr = server.addr();

    let classify_us = Arc::new(Mutex::new(Vec::new()));
    let foldin_us = Arc::new(Mutex::new(Vec::new()));
    let batch_us = Arc::new(Mutex::new(Vec::new()));
    let mut total_requests = 0usize;
    let mut total_elapsed_s = 0.0f64;
    let mut swaps = 0usize;

    let mut suite = BenchSuite::new("serve: hot-swap load");
    suite.bench("classify+foldin+batch across a hot swap", || {
        let barrier = Arc::new(Barrier::new(CLIENTS + 1));
        let t = Instant::now();
        let clients: Vec<_> = (0..CLIENTS)
            .map(|_| {
                let (addr, barrier) = (addr, Arc::clone(&barrier));
                let (c, f, b) = (
                    Arc::clone(&classify_us),
                    Arc::clone(&foldin_us),
                    Arc::clone(&batch_us),
                );
                std::thread::spawn(move || run_client(addr, barrier, c, f, b))
            })
            .collect();
        barrier.wait(); // start
        barrier.wait(); // halfway: swap concurrently with live traffic
        let target = if state.generation() % 2 == 0 {
            &snap_b
        } else {
            &snap_a
        };
        state.swap_model(target).expect("hot swap");
        swaps += 1;
        for c in clients {
            c.join().expect("client");
        }
        total_requests += CLIENTS * PER_CLIENT * ROUND_TRIPS_PER_ITER;
        total_elapsed_s += t.elapsed().as_secs_f64();
    });

    for (name, lat) in [
        ("classify", &classify_us),
        ("foldin", &foldin_us),
        ("batch", &batch_us),
    ] {
        let samples = lat.lock().unwrap();
        suite.metric(&format!("serve.{name}.p50_us"), stats::quantile(&samples, 0.50));
        suite.metric(&format!("serve.{name}.p99_us"), stats::quantile(&samples, 0.99));
    }
    suite.metric("serve.throughput_rps", total_requests as f64 / total_elapsed_s);
    suite.metric("serve.swaps_performed", swaps as f64);
    assert!(
        state.generation() as usize >= swaps,
        "every swap must bump the generation"
    );

    server.stop();
    let _ = std::fs::remove_dir_all(&dir);
}
