//! Bench for Fig. 1: the dense-ALS run whose factor sparsity the figure
//! tabulates (motivation table).

mod common;

use esnmf::nmf::{factorize, NmfOptions};
use esnmf::util::bench::BenchSuite;

fn main() {
    let cfg = common::print_paper_rows("fig1");
    let mut suite = BenchSuite::new("fig1: dense projected ALS (motivation)");
    for name in ["reuters", "wikipedia"] {
        let tdm = common::corpus(name, &cfg);
        let opts = NmfOptions::new(5)
            .with_iters(cfg.iters(30))
            .with_seed(cfg.seed)
            .with_track_error(false);
        suite.bench(&format!("dense_als({name}-sim, k=5)"), || {
            factorize(&tdm, &opts)
        });
    }
}
