//! Bench for the objective seam: the same enforced-sparse blocked ALS
//! run under the Frobenius least-squares objective vs the KL-divergence
//! multiplicative updates. Both share the streaming block geometry and
//! the top-t enforcement machinery, so this suite records what the
//! objective itself costs — wall time per full factorization and the
//! peak-memory telemetry — as *per-objective* metrics: the bench-check
//! gates compare each metric against its own previous trajectory point,
//! never Frobenius against KL (the objectives legitimately differ).

mod common;

use esnmf::nmf::{factorize, NmfOptions, NmfResult, ObjectiveKind, SparsityMode};
use esnmf::util::bench::BenchSuite;

fn main() {
    let cfg = common::bench_config();
    let tdm = common::corpus("reuters", &cfg);
    let k = 5;
    let t = 100;
    let iters = cfg.iters(15);
    let mut suite = BenchSuite::new("objectives: frobenius vs kl");

    for objective in [ObjectiveKind::Frobenius, ObjectiveKind::Kl] {
        let opts = NmfOptions::new(k)
            .with_iters(iters)
            .with_seed(cfg.seed)
            .with_sparsity(SparsityMode::both(t, t))
            .with_threads(1)
            .with_track_error(false)
            .with_objective(objective);
        let mut last: Option<NmfResult> = None;
        suite.bench(&format!("als({})", objective.name()), || {
            last = Some(factorize(&tdm, &opts));
        });
        let r = last.take().expect("bench ran");
        assert!(r.u.nnz() > 0 && r.v.nnz() > 0, "{objective:?} factorized to zero");
        // the peak-memory axis, namespaced by objective so the guarded
        // lower-is-better gates (max_intermediate_nnz) track each
        // objective's own trajectory
        suite.metric(
            &format!("{}.max_intermediate_nnz", objective.name()),
            r.memory.max_intermediate_nnz as f64,
        );
        suite.metric(
            &format!("{}.max_combined_nnz", objective.name()),
            r.memory.max_combined_nnz as f64,
        );
    }
}
