//! Bench for Fig. 6: max-stored-NNZ runs under sparse and dense initial
//! guesses (memory is the figure's metric; time shown for context), plus
//! the blocked-vs-unblocked half-step comparison: the streamed pipeline
//! must hold `max_intermediate_nnz` at O(block_rows · k) per worker
//! while producing bit-identical factors. Peaks are recorded as suite
//! metrics so the merged `BENCH_smoke.json` trajectory carries a memory
//! axis. MemoryStats are captured from the benched runs themselves (the
//! solver is deterministic, so every sample observes identical peaks).

mod common;

use esnmf::nmf::{factorize, NmfOptions, NmfResult, SparsityMode};
use esnmf::util::bench::BenchSuite;

fn main() {
    let cfg = common::print_paper_rows("fig6");
    let tdm = common::corpus("pubmed", &cfg);
    let k = 5;
    let iters = cfg.iters(30);
    let t = 100;
    let mut suite = BenchSuite::new("fig6: memory-tracked runs");

    let sparse_init = NmfOptions::new(k)
        .with_iters(iters)
        .with_seed(cfg.seed)
        .with_sparsity(SparsityMode::both(t, t))
        .with_init_nnz(tdm.n_terms() / 10)
        .with_track_error(false);
    let mut last: Option<NmfResult> = None;
    suite.bench("als(both t=100, sparse init)", || {
        last = Some(factorize(&tdm, &sparse_init));
    });
    let stats = last.take().expect("bench ran").memory;
    suite.metric("sparse_init.max_combined_nnz", stats.max_combined_nnz as f64);
    suite.metric(
        "sparse_init.max_intermediate_nnz",
        stats.max_intermediate_nnz as f64,
    );

    let dense_init = NmfOptions::new(k)
        .with_iters(iters)
        .with_seed(cfg.seed)
        .with_sparsity(SparsityMode::both(t, t))
        .with_track_error(false);
    suite.bench("als(both t=100, dense init)", || {
        last = Some(factorize(&tdm, &dense_init));
    });
    let stats = last.take().expect("bench ran").memory;
    suite.metric("dense_init.max_combined_nnz", stats.max_combined_nnz as f64);
    suite.metric(
        "dense_init.max_intermediate_nnz",
        stats.max_intermediate_nnz as f64,
    );

    // blocked vs unblocked: same factorization, bounded vs full-matrix
    // candidate scratch. block_rows chosen well below the corpus height
    // so the run genuinely crosses many block boundaries.
    let block_rows = (tdm.n_docs().max(tdm.n_terms()) / 8).max(1);
    let blocked_opts = dense_init.clone().with_block_rows(block_rows);
    let unblocked_opts = dense_init.clone().with_block_rows(usize::MAX);
    suite.bench(&format!("als(dense init, block_rows={block_rows})"), || {
        last = Some(factorize(&tdm, &blocked_opts));
    });
    let blocked = last.take().expect("bench ran");
    let mut last_un: Option<NmfResult> = None;
    suite.bench("als(dense init, unblocked)", || {
        last_un = Some(factorize(&tdm, &unblocked_opts));
    });
    let unblocked = last_un.take().expect("bench ran");
    assert_eq!(blocked.u, unblocked.u, "blocked ≡ unblocked factors");
    assert_eq!(blocked.v, unblocked.v, "blocked ≡ unblocked factors");
    suite.metric("blocked.block_rows", block_rows as f64);
    suite.metric(
        "blocked.max_intermediate_nnz",
        blocked.memory.max_intermediate_nnz as f64,
    );
    suite.metric(
        "unblocked.max_intermediate_nnz",
        unblocked.memory.max_intermediate_nnz as f64,
    );
    println!(
        "blocked vs unblocked peak intermediate: {} vs {} scalars (per-worker bound {})",
        blocked.memory.max_intermediate_nnz,
        unblocked.memory.max_intermediate_nnz,
        block_rows * k
    );
}
