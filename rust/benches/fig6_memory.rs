//! Bench for Fig. 6: max-stored-NNZ runs under sparse and dense initial
//! guesses (memory is the figure's metric; time shown for context).

mod common;

use esnmf::nmf::{factorize, NmfOptions, SparsityMode};
use esnmf::util::bench::BenchSuite;

fn main() {
    let cfg = common::print_paper_rows("fig6");
    let tdm = common::corpus("pubmed", &cfg);
    let iters = cfg.iters(30);
    let t = 100;
    let mut suite = BenchSuite::new("fig6: memory-tracked runs");
    let sparse_init = NmfOptions::new(5)
        .with_iters(iters)
        .with_seed(cfg.seed)
        .with_sparsity(SparsityMode::both(t, t))
        .with_init_nnz(tdm.n_terms() / 10)
        .with_track_error(false);
    suite.bench("als(both t=100, sparse init)", || {
        factorize(&tdm, &sparse_init)
    });
    let dense_init = NmfOptions::new(5)
        .with_iters(iters)
        .with_seed(cfg.seed)
        .with_sparsity(SparsityMode::both(t, t))
        .with_track_error(false);
    suite.bench("als(both t=100, dense init)", || {
        factorize(&tdm, &dense_init)
    });
}
