//! Bench for Fig. 6: max-stored-NNZ runs under sparse and dense initial
//! guesses (memory is the figure's metric; time shown for context), plus
//! the blocked-vs-unblocked half-step comparison: the streamed pipeline
//! must hold `max_intermediate_nnz` at O(block_rows · k) per worker
//! while producing bit-identical factors — and the out-of-core corpus
//! store, whose resident-corpus peak (bytes of shards in flight) must
//! undercut the full on-disk matrix. Peaks are recorded as suite
//! metrics so the merged `BENCH_smoke.json` trajectory carries a memory
//! axis (and the `bench-check` CI gate can flag regressions).
//! MemoryStats are captured from the benched runs themselves (the
//! solver is deterministic, so every sample observes identical peaks).

mod common;

use esnmf::io::CorpusStore;
use esnmf::nmf::{factorize, factorize_corpus, NmfOptions, NmfResult, SparsityMode};
use esnmf::util::bench::BenchSuite;

fn main() {
    let cfg = common::print_paper_rows("fig6");
    let tdm = common::corpus("pubmed", &cfg);
    let k = 5;
    let iters = cfg.iters(30);
    let t = 100;
    let mut suite = BenchSuite::new("fig6: memory-tracked runs");

    let sparse_init = NmfOptions::new(k)
        .with_iters(iters)
        .with_seed(cfg.seed)
        .with_sparsity(SparsityMode::both(t, t))
        .with_init_nnz(tdm.n_terms() / 10)
        .with_track_error(false);
    let mut last: Option<NmfResult> = None;
    suite.bench("als(both t=100, sparse init)", || {
        last = Some(factorize(&tdm, &sparse_init));
    });
    let stats = last.take().expect("bench ran").memory;
    suite.metric("sparse_init.max_combined_nnz", stats.max_combined_nnz as f64);
    suite.metric(
        "sparse_init.max_intermediate_nnz",
        stats.max_intermediate_nnz as f64,
    );

    let dense_init = NmfOptions::new(k)
        .with_iters(iters)
        .with_seed(cfg.seed)
        .with_sparsity(SparsityMode::both(t, t))
        .with_track_error(false);
    suite.bench("als(both t=100, dense init)", || {
        last = Some(factorize(&tdm, &dense_init));
    });
    let stats = last.take().expect("bench ran").memory;
    suite.metric("dense_init.max_combined_nnz", stats.max_combined_nnz as f64);
    suite.metric(
        "dense_init.max_intermediate_nnz",
        stats.max_intermediate_nnz as f64,
    );

    // blocked vs unblocked: same factorization, bounded vs full-matrix
    // candidate scratch. block_rows chosen well below the corpus height
    // so the run genuinely crosses many block boundaries.
    let block_rows = (tdm.n_docs().max(tdm.n_terms()) / 8).max(1);
    let blocked_opts = dense_init.clone().with_block_rows(block_rows);
    let unblocked_opts = dense_init.clone().with_block_rows(usize::MAX);
    suite.bench(&format!("als(dense init, block_rows={block_rows})"), || {
        last = Some(factorize(&tdm, &blocked_opts));
    });
    let blocked = last.take().expect("bench ran");
    let mut last_un: Option<NmfResult> = None;
    suite.bench("als(dense init, unblocked)", || {
        last_un = Some(factorize(&tdm, &unblocked_opts));
    });
    let unblocked = last_un.take().expect("bench ran");
    assert_eq!(blocked.u, unblocked.u, "blocked ≡ unblocked factors");
    assert_eq!(blocked.v, unblocked.v, "blocked ≡ unblocked factors");
    suite.metric("blocked.block_rows", block_rows as f64);
    suite.metric(
        "blocked.max_intermediate_nnz",
        blocked.memory.max_intermediate_nnz as f64,
    );
    suite.metric(
        "unblocked.max_intermediate_nnz",
        unblocked.memory.max_intermediate_nnz as f64,
    );
    println!(
        "blocked vs unblocked peak intermediate: {} vs {} scalars (per-worker bound {})",
        blocked.memory.max_intermediate_nnz,
        unblocked.memory.max_intermediate_nnz,
        block_rows * k
    );

    // out-of-core: the same blocked factorization streamed from an
    // .estdm store — bit-identical factors, resident corpus bounded by
    // the shards in flight instead of the whole matrix
    let store_path = std::env::temp_dir().join("esnmf_fig6_bench.estdm");
    let _ = std::fs::remove_file(&store_path);
    let shard_rows = (tdm.n_docs().max(tdm.n_terms()) / 16).max(1);
    CorpusStore::write(&store_path, &tdm, shard_rows).expect("writing bench store");
    let store = CorpusStore::open(&store_path).expect("opening bench store");
    // one worker ⇒ one shard cursor ⇒ the resident peak is a
    // deterministic function of the (fixed smoke-mode) corpus, so the
    // bench-check CI gate can guard it without scheduling jitter; the
    // factors are bit-identical at any thread count regardless
    let store_opts = blocked_opts.clone().with_threads(1);
    let mut last_store: Option<NmfResult> = None;
    suite.bench(
        &format!("als(dense init, corpus-store, block_rows={block_rows})"),
        || {
            last_store = Some(factorize_corpus(&store, &store_opts));
        },
    );
    let streamed = last_store.take().expect("bench ran");
    assert_eq!(streamed.u, blocked.u, "store-streamed ≡ in-memory factors");
    assert_eq!(streamed.v, blocked.v, "store-streamed ≡ in-memory factors");
    suite.metric("store.shard_rows", shard_rows as f64);
    suite.metric(
        "store.resident_corpus_peak_bytes",
        store.resident().peak() as f64,
    );
    suite.metric("store.corpus_payload_bytes", store.payload_bytes() as f64);
    println!(
        "store-streamed resident corpus peak: {} of {} payload bytes ({} + {} shards)",
        store.resident().peak(),
        store.payload_bytes(),
        store.terms_major().n_shards(),
        store.docs_major().n_shards(),
    );
    drop(store);
    let _ = std::fs::remove_file(&store_path);
}
