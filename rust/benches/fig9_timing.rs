//! Bench for Fig. 9 — the paper's timing figure: 100 ALS iterations under
//! whole-matrix, column-wise and sequential enforcement. This is the
//! headline performance comparison; EXPERIMENTS.md records the ratios.

mod common;

use esnmf::nmf::{
    factorize, factorize_sequential, NmfOptions, SequentialOptions, SparsityMode,
};
use esnmf::util::bench::BenchSuite;

fn main() {
    let cfg = common::print_paper_rows("fig9");
    let tdm = common::corpus("pubmed", &cfg);
    let k = 5;
    let iters = cfg.iters(100);
    let t_u = 50;
    let t_v = 500.min(tdm.n_docs());
    let mut suite = BenchSuite::new("fig9: 100-iteration timing");
    // pin thread counts explicitly: the paper's figure is single-core,
    // the parallel rows show the same run saturating the worker pool
    // (bit-identical output — see the determinism contract in als)
    let normal = NmfOptions::new(k)
        .with_iters(iters)
        .with_seed(cfg.seed)
        .with_sparsity(SparsityMode::both(t_u, t_v))
        .with_track_error(false)
        .with_threads(1);
    suite.bench("normal (whole-matrix, serial)", || factorize(&tdm, &normal));
    for threads in [2usize, 4] {
        let par = normal.clone().with_threads(threads);
        suite.bench(&format!("normal (whole-matrix, threads={threads})"), || {
            factorize(&tdm, &par)
        });
    }
    let colwise = NmfOptions::new(k)
        .with_iters(iters)
        .with_seed(cfg.seed)
        .with_sparsity(SparsityMode::PerColumn {
            t_u_col: Some(t_u / k),
            t_v_col: Some(t_v / k),
        })
        .with_track_error(false)
        .with_threads(1);
    suite.bench("column-wise", || factorize(&tdm, &colwise));
    let seq = SequentialOptions::new(k, iters / k)
        .with_budgets(t_u / k, t_v / k)
        .with_seed(cfg.seed);
    suite.bench("sequential", || factorize_sequential(&tdm, &seq));

    // ratios the paper reports (sequential fastest), plus the parallel
    // speedup of the same whole-matrix configuration
    let ns = suite.results[0].median_s();
    let p2 = suite.results[1].median_s();
    let p4 = suite.results[2].median_s();
    let cs = suite.results[3].median_s();
    let ss = suite.results[4].median_s();
    println!("\nFig. 9 ratios: column-wise/normal = {:.2}x, sequential/normal = {:.2}x", cs / ns, ss / ns);
    println!("parallel speedup (whole-matrix): 2 threads = {:.2}x, 4 threads = {:.2}x", ns / p2, ns / p4);
}
