//! Micro-benchmarks of the sparse hot-path kernels — the profile targets
//! of the L3 performance pass (EXPERIMENTS.md §Perf).

mod common;

use esnmf::nmf::{half_step_v, init, MemoryTracker, NmfOptions, SparsityMode};
use esnmf::sparse::{ops, topk, RowBlock, RowCursor, TieMode};
use esnmf::util::bench::BenchSuite;
use esnmf::util::rng::Rng;

fn main() {
    let cfg = common::bench_config();
    let tdm = common::corpus("pubmed", &cfg);
    let k = 5;
    let mut rng = Rng::new(cfg.seed);
    let u = init::dense_random(tdm.n_terms(), k, &mut rng);
    let u_sparse = init::sparse_random(tdm.n_terms(), k, tdm.n_terms() / 5, &mut rng);
    let v = init::dense_random(tdm.n_docs(), k, &mut rng);

    let mut suite = BenchSuite::new("micro: sparse kernels");
    let atb_serial = suite
        .bench("atb(A^T·U dense-U)", || ops::atb(&tdm.a_csc, &u))
        .median_s();
    suite.bench("atb(A^T·U sparse-U)", || ops::atb(&tdm.a_csc, &u_sparse));
    let ab_serial = suite.bench("ab(A·V)", || ops::ab(&tdm.a, &v)).median_s();
    let mut atb_par4 = f64::NAN;
    let mut ab_par4 = f64::NAN;
    for threads in [2usize, 4, 8] {
        let a = suite
            .bench(&format!("atb_par(threads={threads})"), || {
                ops::atb_par(&tdm.a_csc, &u, threads)
            })
            .median_s();
        let b = suite
            .bench(&format!("ab_par(threads={threads})"), || {
                ops::ab_par(&tdm.a, &v, threads)
            })
            .median_s();
        if threads == 4 {
            atb_par4 = a;
            ab_par4 = b;
        }
    }
    let gram_serial = suite.bench("gram(U)", || ops::gram(&u)).median_s();
    let gram_par4 = suite
        .bench("gram_par(U, threads=4)", || ops::gram_par(&u, 4))
        .median_s();
    suite.bench("tr_cross(A,U,V)", || ops::tr_cross(&tdm.a, &u, &v));

    // before/after points for the kernel restructure: the live chunked
    // SpMM / dense-gather gram / touched-clear error trace next to the
    // verbatim pre-restructure loops kept in ops::reference. Both SpMM
    // sides read the same dense_factor copy, so the ratio isolates the
    // accumulator layout, not the densification cost.
    let rows = tdm.n_terms();
    let v_dense = ops::dense_factor(&v);
    let spmm_dense_new = suite
        .bench("stream_mul(dense-V, chunked)", || {
            ops::stream_mul_par_with(&tdm.a, &v, v_dense.as_deref(), None, 1)
        })
        .median_s();
    let spmm_dense_ref = suite
        .bench("stream_mul(dense-V, reference)", || {
            let mut cur = RowCursor::new();
            let mut out = RowBlock::new(rows, k);
            ops::reference::stream_mul_into_ref(
                &tdm.a,
                &v,
                v_dense.as_deref(),
                None,
                0,
                rows,
                &mut cur,
                &mut out,
            );
            out
        })
        .median_s();
    suite.metric("spmm.chunked_speedup_dense", spmm_dense_ref / spmm_dense_new);
    let v_sparse = init::sparse_random(tdm.n_docs(), k, tdm.n_docs() / 5, &mut rng);
    let spmm_sparse_new = suite
        .bench("stream_mul(sparse-V, touched-clear)", || {
            ops::stream_mul_par_with(&tdm.a, &v_sparse, None, None, 1)
        })
        .median_s();
    let spmm_sparse_ref = suite
        .bench("stream_mul(sparse-V, reference)", || {
            let mut cur = RowCursor::new();
            let mut out = RowBlock::new(rows, k);
            ops::reference::stream_mul_into_ref(
                &tdm.a,
                &v_sparse,
                None,
                None,
                0,
                rows,
                &mut cur,
                &mut out,
            );
            out
        })
        .median_s();
    suite.metric("spmm.touched_clear_speedup_sparse", spmm_sparse_ref / spmm_sparse_new);
    let gram_fast = suite.bench("gram(U, fast path)", || ops::gram(&u)).median_s();
    let gram_ref = suite
        .bench("gram(U, reference)", || ops::reference::gram_ref(&u))
        .median_s();
    suite.metric("gram.fastpath_speedup", gram_ref / gram_fast);
    // the error trace at a wide rank (k = 64) on sparse factors — the
    // regime where the old full-width scratch memset dominated
    let kw = 64;
    let uw = init::sparse_random(tdm.n_terms(), kw, tdm.n_terms() * 2, &mut rng);
    let vw = init::sparse_random(tdm.n_docs(), kw, tdm.n_docs() * 2, &mut rng);
    let trace_chunk = (tdm.n_terms() / 8).max(1);
    let trace_new = suite
        .bench("tr_cross(k=64 sparse, touched-clear)", || {
            ops::tr_cross_source(&tdm.a, &uw, &vw, trace_chunk)
        })
        .median_s();
    let trace_ref = suite
        .bench("tr_cross(k=64 sparse, reference)", || {
            ops::reference::tr_cross_source_ref(&tdm.a, &uw, &vw, trace_chunk)
        })
        .median_s();
    suite.metric("error_trace.touched_clear_speedup", trace_ref / trace_new);

    // top-t selection: quickselect vs the paper's full sort
    let vals: Vec<f32> = (0..200_000).map(|_| rng.f32()).collect();
    let t = 5_000;
    suite.bench("nth_largest(quickselect)", || {
        let mut copy = vals.clone();
        topk::nth_largest(&mut copy, t)
    });
    suite.bench("nth_largest(full sort)", || {
        topk::nth_largest_by_sort(&vals, t)
    });

    // enforcement on a factor-sized matrix
    let big = init::dense_random(tdm.n_docs(), k, &mut rng);
    suite.bench("enforce_top_t_csr", || {
        let mut m = big.clone();
        topk::enforce_top_t_csr(&mut m, t, TieMode::KeepTies);
        m
    });
    suite.bench("enforce_top_t_per_column", || {
        let mut m = big.clone();
        topk::enforce_top_t_per_column(&mut m, t / k, TieMode::KeepTies);
        m
    });
    let big_rb = RowBlock::from_csr(&big);
    let enforce_serial = suite
        .bench("enforce_top_t_rowblock(serial)", || {
            let mut rb = big_rb.clone();
            topk::enforce_top_t_rowblock(&mut rb, t, TieMode::KeepTies);
            rb
        })
        .median_s();
    let mut enforce_par4 = f64::NAN;
    for threads in [2usize, 4, 8] {
        let s = suite
            .bench(&format!("enforce_top_t_rowblock(threads={threads})"), || {
                let mut rb = big_rb.clone();
                topk::enforce_top_t_rowblock_par(&mut rb, t, TieMode::KeepTies, threads);
                rb
            })
            .median_s();
        if threads == 4 {
            enforce_par4 = s;
        }
    }

    // the fused streamed half-step (candidate → solve → enforce per row
    // block): blocked vs single-block timings at the same thread count —
    // the memory bound is supposed to cost ~one extra SpMM sweep in
    // global-enforcement mode, nothing more
    let half_opts = NmfOptions::new(k)
        .with_seed(cfg.seed)
        .with_sparsity(SparsityMode::both(t, t))
        .with_threads(4);
    let blocked_rows = (tdm.n_docs() / 8).max(1);
    let half_blocked = suite
        .bench(
            &format!("half_step_v(block_rows={blocked_rows}, threads=4)"),
            || {
                let mut mem = MemoryTracker::new();
                half_step_v(
                    &tdm.a_csc,
                    &u,
                    &half_opts.clone().with_block_rows(blocked_rows),
                    &mut mem,
                )
            },
        )
        .median_s();
    let half_unblocked = suite
        .bench("half_step_v(unblocked, threads=4)", || {
            let mut mem = MemoryTracker::new();
            half_step_v(
                &tdm.a_csc,
                &u,
                &half_opts.clone().with_block_rows(usize::MAX),
                &mut mem,
            )
        })
        .median_s();
    suite.metric("half_step_v.blocked_over_unblocked", half_blocked / half_unblocked);

    // disabled-tracing overhead contract: the same kernel with a trace
    // span around every call vs without. Tracing stays off, so each span
    // costs one relaxed counter bump + branch; the CI gate is
    // `bench-check --absolute trace.overhead_x=1.05`. Measured as the
    // median of interleaved round ratios (robust to smoke mode's single
    // suite sample) rather than two far-apart suite timings.
    assert!(
        !esnmf::util::trace::enabled(),
        "overhead_x measures the *disabled* span path"
    );
    let mut ratios: Vec<f64> = (0..9)
        .map(|_| {
            use std::hint::black_box;
            let t = std::time::Instant::now();
            for _ in 0..8 {
                black_box(ops::gram(black_box(&u)));
            }
            let bare = t.elapsed().as_secs_f64();
            let t = std::time::Instant::now();
            for _ in 0..8 {
                let _span = esnmf::util::trace::span("bench_overhead");
                black_box(ops::gram(black_box(&u)));
            }
            t.elapsed().as_secs_f64() / bare.max(f64::MIN_POSITIVE)
        })
        .collect();
    ratios.sort_by(|a, b| a.total_cmp(b));
    suite.metric("trace.overhead_x", ratios[ratios.len() / 2]);

    // serial/parallel speedups at 4 workers — the numbers the parallel
    // hot path exists for (>1.5x expected on the SpMM and enforcement
    // kernels at the PubMed preset size)
    println!(
        "\nspeedup at 4 threads: atb {:.2}x  ab {:.2}x  gram {:.2}x  enforce {:.2}x",
        atb_serial / atb_par4,
        ab_serial / ab_par4,
        gram_serial / gram_par4,
        enforce_serial / enforce_par4
    );
}
