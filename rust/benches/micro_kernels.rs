//! Micro-benchmarks of the sparse hot-path kernels — the profile targets
//! of the L3 performance pass (EXPERIMENTS.md §Perf).

mod common;

use esnmf::nmf::init;
use esnmf::sparse::{ops, topk, TieMode};
use esnmf::util::bench::BenchSuite;
use esnmf::util::rng::Rng;

fn main() {
    let cfg = common::bench_config();
    let tdm = common::corpus("pubmed", &cfg);
    let k = 5;
    let mut rng = Rng::new(cfg.seed);
    let u = init::dense_random(tdm.n_terms(), k, &mut rng);
    let u_sparse = init::sparse_random(tdm.n_terms(), k, tdm.n_terms() / 5, &mut rng);
    let v = init::dense_random(tdm.n_docs(), k, &mut rng);

    let mut suite = BenchSuite::new("micro: sparse kernels");
    suite.bench("atb(A^T·U dense-U)", || ops::atb(&tdm.a_csc, &u));
    suite.bench("atb(A^T·U sparse-U)", || ops::atb(&tdm.a_csc, &u_sparse));
    suite.bench("ab(A·V)", || ops::ab(&tdm.a, &v));
    for threads in [2usize, 4, 8] {
        suite.bench(&format!("atb_par(threads={threads})"), || {
            ops::atb_par(&tdm.a_csc, &u, threads)
        });
        suite.bench(&format!("ab_par(threads={threads})"), || {
            ops::ab_par(&tdm.a, &v, threads)
        });
    }
    suite.bench("gram(U)", || ops::gram(&u));
    suite.bench("tr_cross(A,U,V)", || ops::tr_cross(&tdm.a, &u, &v));

    // top-t selection: quickselect vs the paper's full sort
    let vals: Vec<f32> = (0..200_000).map(|_| rng.f32()).collect();
    let t = 5_000;
    suite.bench("nth_largest(quickselect)", || {
        let mut copy = vals.clone();
        topk::nth_largest(&mut copy, t)
    });
    suite.bench("nth_largest(full sort)", || {
        topk::nth_largest_by_sort(&vals, t)
    });

    // enforcement on a factor-sized matrix
    let big = init::dense_random(tdm.n_docs(), k, &mut rng);
    suite.bench("enforce_top_t_csr", || {
        let mut m = big.clone();
        topk::enforce_top_t_csr(&mut m, t, TieMode::KeepTies);
        m
    });
    suite.bench("enforce_top_t_per_column", || {
        let mut m = big.clone();
        topk::enforce_top_t_per_column(&mut m, t / k, TieMode::KeepTies);
        m
    });
}
