#![allow(dead_code)] // each bench target uses a subset of these helpers

//! Shared bench-target plumbing.
//!
//! Pattern for every paper bench: (1) regenerate the figure's rows once
//! via the experiment harness, (2) time the figure's core solver
//! configuration directly (no printing inside the timed region).

use esnmf::corpus::Scale;
use esnmf::experiments::{self, ExpConfig};
use esnmf::text::TermDocMatrix;

/// Scale for bench runs: `ESNMF_BENCH_SCALE=tiny|small|paper` (default
/// tiny so `cargo bench` completes quickly; use small/paper for the
/// numbers recorded in EXPERIMENTS.md). `BENCH_SMOKE=1` overrides to
/// tiny + fast regardless, so CI's bench-smoke job stays quick.
pub fn bench_config() -> ExpConfig {
    if esnmf::util::bench::smoke_mode() {
        return ExpConfig {
            scale: Scale::Tiny,
            seed: 42,
            fast: true,
        };
    }
    let scale = std::env::var("ESNMF_BENCH_SCALE")
        .ok()
        .and_then(|s| Scale::parse(&s))
        .unwrap_or(Scale::Tiny);
    ExpConfig {
        scale,
        seed: 42,
        fast: esnmf::util::bench::fast_mode(),
    }
}

/// Print the paper rows for `id` once.
pub fn print_paper_rows(id: &str) -> ExpConfig {
    let cfg = bench_config();
    experiments::run(id, &cfg).expect("experiment failed");
    cfg
}

pub fn corpus(name: &str, cfg: &ExpConfig) -> TermDocMatrix {
    experiments::corpus_tdm(name, cfg).expect("corpus preset")
}
