//! Bench for Fig. 3: enforced-sparsity ALS across the NNZ sweep (time per
//! enforcement variant at a representative budget).

mod common;

use esnmf::nmf::{factorize, NmfOptions, SparsityMode};
use esnmf::util::bench::BenchSuite;

fn main() {
    let cfg = common::print_paper_rows("fig3");
    let tdm = common::corpus("reuters", &cfg);
    let iters = cfg.iters(75);
    let t = 200;
    let mut suite = BenchSuite::new("fig3: enforcement variants");
    for (name, mode) in [
        ("U only", SparsityMode::u_only(t)),
        ("V only", SparsityMode::v_only(t)),
        ("both", SparsityMode::both(t, t)),
    ] {
        let opts = NmfOptions::new(5)
            .with_iters(iters)
            .with_seed(cfg.seed)
            .with_sparsity(mode)
            .with_track_error(false);
        suite.bench(&format!("als(enforce {name}, t={t})"), || {
            factorize(&tdm, &opts)
        });
    }
}
