//! Bench for Fig. 2: sparse-U(55) vs dense projected ALS on reuters-sim.

mod common;

use esnmf::nmf::{factorize, NmfOptions, SparsityMode};
use esnmf::util::bench::BenchSuite;

fn main() {
    let cfg = common::print_paper_rows("fig2");
    let tdm = common::corpus("reuters", &cfg);
    let iters = cfg.iters(75);
    let mut suite = BenchSuite::new("fig2: convergence runs");
    let sparse = NmfOptions::new(5)
        .with_iters(iters)
        .with_seed(cfg.seed)
        .with_sparsity(SparsityMode::u_only(55));
    suite.bench("als(sparse U=55)", || factorize(&tdm, &sparse));
    let dense = NmfOptions::new(5).with_iters(iters).with_seed(cfg.seed);
    suite.bench("als(dense)", || factorize(&tdm, &dense));
}
