//! Ablation (DESIGN.md design-choice): top-t enforcement (Algorithm 2)
//! versus the "simpler method" the paper §2 dismisses — a fixed magnitude
//! threshold. Shows (a) the runtime cost of selection is small and
//! (b) the threshold gives no control over NNZ, which drifts with the
//! factor scaling across iterations.

mod common;

use esnmf::nmf::{factorize, NmfOptions, SparsityMode};
use esnmf::util::bench::BenchSuite;

fn main() {
    let cfg = common::bench_config();
    let tdm = common::corpus("reuters", &cfg);
    let k = 5;
    let iters = cfg.iters(40);
    let t = 200;

    let mut suite = BenchSuite::new("ablation: top-t vs fixed threshold");
    let top_t = NmfOptions::new(k)
        .with_iters(iters)
        .with_seed(cfg.seed)
        .with_sparsity(SparsityMode::both(t, t))
        .with_track_error(false);
    let r_top = factorize(&tdm, &top_t);
    suite.bench("enforce top-t (selection)", || factorize(&tdm, &top_t));

    // calibrate the threshold so that *at the end* it would give roughly
    // the same nnz as top-t — then show it does NOT hold through the run
    let mut vals: Vec<f32> = r_top.u.values.clone();
    vals.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let tau = vals.last().copied().unwrap_or(1e-3);
    let thresh = NmfOptions::new(k)
        .with_iters(iters)
        .with_seed(cfg.seed)
        .with_sparsity(SparsityMode::Threshold {
            tau_u: Some(tau),
            tau_v: Some(tau),
        })
        .with_track_error(false);
    let r_thresh = factorize(&tdm, &thresh);
    suite.bench("enforce fixed threshold", || factorize(&tdm, &thresh));

    suite.table("NNZ control (the reason the paper picks top-t)");
    println!("method | target | final nnz(U) | final nnz(V)");
    println!("top-t | {t} | {} | {}", r_top.u.nnz(), r_top.v.nnz());
    println!(
        "threshold(tau={tau:.2e}) | uncontrolled | {} | {}",
        r_thresh.u.nnz(),
        r_thresh.v.nnz()
    );
    let drift = (r_thresh.u.nnz() as f64 - t as f64).abs() / t as f64;
    println!("threshold nnz drift from target: {:.0}%", drift * 100.0);
}
