//! Bench for Fig. 8: sequential and column-wise accuracy runs on
//! pubmed-sim.

mod common;

use esnmf::nmf::{
    factorize, factorize_sequential, NmfOptions, SequentialOptions, SparsityMode,
};
use esnmf::util::bench::BenchSuite;

fn main() {
    let cfg = common::print_paper_rows("fig8");
    let tdm = common::corpus("pubmed", &cfg);
    let t_col = (tdm.n_docs() / 10).max(2);
    let mut suite = BenchSuite::new("fig8: per-topic budget runs");
    let colwise = NmfOptions::new(5)
        .with_iters(cfg.iters(50))
        .with_seed(cfg.seed)
        .with_sparsity(SparsityMode::PerColumn {
            t_u_col: None,
            t_v_col: Some(t_col),
        })
        .with_track_error(false);
    suite.bench("column-wise V budget", || factorize(&tdm, &colwise));
    let seq = SequentialOptions::new(5, cfg.iters(10))
        .with_budgets(tdm.n_terms(), t_col)
        .with_seed(cfg.seed);
    suite.bench("sequential V budget", || factorize_sequential(&tdm, &seq));
}
