//! Bench for the distributed plane: the same blocked, store-streamed
//! factorization run single-process vs scattered over loopback workers.
//! The distributed run must produce bit-identical factors (asserted via
//! full factor equality *and* `NmfResult::digest`), so the only thing
//! this suite measures is the wire overhead of the scatter/merge path —
//! recorded as `wall_s_*` metrics the `bench-check --guards wall_s` CI
//! gate can watch.

mod common;

use std::net::TcpListener;
use std::path::Path;
use std::time::Duration;

use esnmf::coordinator::{run_distributed_on, run_worker, DistOptions};
use esnmf::io::CorpusStore;
use esnmf::nmf::{factorize_corpus, NmfOptions, NmfResult, SparsityMode};
use esnmf::util::bench::BenchSuite;

/// One full distributed run: bind an ephemeral loopback port, spawn
/// `workers` in-process workers against it, drive the coordinator, and
/// join the workers after the shutdown frame.
fn distributed(
    store: &CorpusStore,
    store_path: &Path,
    opts: &NmfOptions,
    workers: usize,
) -> NmfResult {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback listener");
    let addr = listener.local_addr().expect("listener addr").to_string();
    let objective = opts.objective;
    let handles: Vec<_> = (0..workers)
        .map(|_| {
            let path = store_path.to_path_buf();
            let addr = addr.clone();
            std::thread::spawn(move || run_worker(&path, &addr, objective, 1))
        })
        .collect();
    let dopts = DistOptions {
        listen: addr,
        workers,
        timeout: Duration::from_secs(60),
    };
    let result = run_distributed_on(listener, store, opts, &dopts).expect("distributed run");
    for h in handles {
        h.join().expect("worker thread").expect("worker exits cleanly");
    }
    result
}

fn main() {
    let cfg = common::bench_config();
    let tdm = common::corpus("pubmed", &cfg);
    let k = 5;
    let t = 100;
    let iters = cfg.iters(20);
    // well below the corpus height so the run genuinely scatters spans
    let block_rows = (tdm.n_docs().max(tdm.n_terms()) / 8).max(1);
    let mut suite = BenchSuite::new("distributed: loopback workers vs single-process");

    let store_path = std::env::temp_dir().join("esnmf_dist_bench.estdm");
    let _ = std::fs::remove_file(&store_path);
    let shard_rows = (tdm.n_docs().max(tdm.n_terms()) / 16).max(1);
    CorpusStore::write(&store_path, &tdm, shard_rows).expect("writing bench store");
    let store = CorpusStore::open(&store_path).expect("opening bench store");

    let opts = NmfOptions::new(k)
        .with_iters(iters)
        .with_seed(cfg.seed)
        .with_sparsity(SparsityMode::both(t, t))
        .with_block_rows(block_rows)
        .with_threads(1)
        .with_track_error(false);

    let mut last: Option<NmfResult> = None;
    let local_s = suite
        .bench("als(corpus-store, single-process)", || {
            last = Some(factorize_corpus(&store, &opts));
        })
        .median_s();
    let local = last.take().expect("bench ran");

    let workers = 2;
    let mut last_dist: Option<NmfResult> = None;
    let dist_s = suite
        .bench(&format!("als(corpus-store, {workers} loopback workers)"), || {
            last_dist = Some(distributed(&store, &store_path, &opts, workers));
        })
        .median_s();
    let dist = last_dist.take().expect("bench ran");

    assert_eq!(dist.u, local.u, "distributed ≡ single-process factors");
    assert_eq!(dist.v, local.v, "distributed ≡ single-process factors");
    assert_eq!(
        dist.digest(),
        local.digest(),
        "distributed ≡ single-process digest"
    );

    suite.metric("dist.workers", workers as f64);
    suite.metric("dist.block_rows", block_rows as f64);
    suite.metric("dist.overhead_x", dist_s / local_s.max(1e-12));
    println!(
        "factors digest: {:#018x} (identical at {} workers; wire overhead {:.2}x)",
        dist.digest(),
        workers,
        dist_s / local_s.max(1e-12)
    );

    drop(store);
    let _ = std::fs::remove_file(&store_path);
}
