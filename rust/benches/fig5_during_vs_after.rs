//! Bench for Fig. 5: enforce-during (Algorithm 2) vs enforce-after
//! (Algorithm 1 + one post-hoc top-t).

mod common;

use esnmf::nmf::{factorize, NmfOptions, SparsityMode};
use esnmf::sparse::{topk, TieMode};
use esnmf::util::bench::BenchSuite;

fn main() {
    let cfg = common::print_paper_rows("fig5");
    let tdm = common::corpus("pubmed", &cfg);
    let iters = cfg.iters(50);
    let t = 100;
    let mut suite = BenchSuite::new("fig5: during vs after");
    let during = NmfOptions::new(5)
        .with_iters(iters)
        .with_seed(cfg.seed)
        .with_sparsity(SparsityMode::both(t, t))
        .with_track_error(false);
    suite.bench("enforce during ALS", || factorize(&tdm, &during));
    let dense = NmfOptions::new(5)
        .with_iters(iters)
        .with_seed(cfg.seed)
        .with_track_error(false);
    suite.bench("dense ALS + enforce after", || {
        let r = factorize(&tdm, &dense);
        let mut u = r.u;
        let mut v = r.v;
        topk::enforce_top_t_csr(&mut u, t, TieMode::KeepTies);
        topk::enforce_top_t_csr(&mut v, t, TieMode::KeepTies);
        (u, v)
    });
}
