//! Bench for Fig. 4: accuracy-vs-NNZ runs on pubmed-sim (time of one
//! sweep point per enforcement variant, plus the accuracy evaluation).

mod common;

use esnmf::eval::mean_topic_accuracy;
use esnmf::nmf::{factorize, NmfOptions, SparsityMode};
use esnmf::util::bench::BenchSuite;

fn main() {
    let cfg = common::print_paper_rows("fig4");
    let tdm = common::corpus("pubmed", &cfg);
    let labels = tdm.doc_labels.clone().unwrap();
    let iters = cfg.iters(50);
    let t = 100;
    let mut suite = BenchSuite::new("fig4: accuracy sweep point");
    let opts = NmfOptions::new(5)
        .with_iters(iters)
        .with_seed(cfg.seed)
        .with_sparsity(SparsityMode::both(t, t))
        .with_track_error(false);
    let result = factorize(&tdm, &opts);
    suite.bench("als(both, t=100)", || factorize(&tdm, &opts));
    suite.bench("eq3.3 accuracy eval", || {
        mean_topic_accuracy(&result.v, &labels, tdm.label_names.len())
    });
}
