//! End-to-end driver — the full system on a real small workload, proving
//! all layers compose (recorded in EXPERIMENTS.md §End-to-end):
//!
//! 1. generate a labeled pubmed-sim corpus,
//! 2. stream it through the backpressured ingestion pipeline,
//! 3. factorize concurrently under several configurations via the job
//!    manager (native sparse backend),
//! 4. cross-check the XLA/PJRT artifact backend on a fitted subproblem,
//! 5. serve the best model over TCP and run batched queries, reporting
//!    latency and throughput.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pipeline -- [scale]
//! ```

use esnmf::backend::{AlsBackend, XlaBackend};
use esnmf::coordinator::ingest::{ingest_stream, IngestConfig, RawDoc};
use esnmf::coordinator::{JobManager, JobSpec, MetricsRegistry, TopicModel, TopicServer};
use esnmf::corpus::{self, Scale};
use esnmf::eval::mean_topic_accuracy;
use esnmf::eval::topics::format_topic_table;
use esnmf::eval::topics::topic_term_table;
use esnmf::nmf::{NmfOptions, SequentialOptions, SparsityMode};
use esnmf::runtime::{self, ProgramKind, XlaExecutor};
use esnmf::util::stats;
use esnmf::util::timer::Timer;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let scale = std::env::args()
        .nth(1)
        .and_then(|s| Scale::parse(&s))
        .unwrap_or(Scale::Small);
    let metrics = MetricsRegistry::new();
    let total = Timer::start();

    // ---- 1+2. streaming ingestion with backpressure --------------------
    let spec = corpus::pubmed_sim(scale);
    let docs = corpus::generate(&spec, 42);
    let n_raw = docs.len();
    let ingest_timer = Timer::start();
    let stream = docs.into_iter().map(|d| RawDoc {
        text: d.tokens.join(" "),
        label: Some(spec.topics[d.label as usize].name.clone()),
    });
    let (tdm, count) = ingest_stream(
        stream,
        &IngestConfig {
            workers: 4,
            capacity: 128,
        },
    );
    let ingest_s = ingest_timer.elapsed_s();
    metrics.counter("ingest.docs").add(count as u64);
    println!(
        "[ingest] {count}/{n_raw} docs → {} terms × {} docs ({:.2}% sparse) in {ingest_s:.2}s ({:.0} docs/s)",
        tdm.n_terms(),
        tdm.n_docs(),
        tdm.a.sparsity() * 100.0,
        count as f64 / ingest_s
    );

    // ---- 3. concurrent factorization jobs ------------------------------
    let tdm = Arc::new(tdm);
    let labels = tdm.doc_labels.clone().expect("labeled corpus");
    let n_journals = tdm.label_names.len();
    let mgr = JobManager::new(4);
    let fact_timer = Timer::start();
    let configs: Vec<(String, JobSpec)> = vec![
        (
            "dense ALS (Alg.1)".into(),
            JobSpec::Als(NmfOptions::new(5).with_iters(50).with_seed(42).with_track_error(false)),
        ),
        (
            "enforced both t=200 (Alg.2)".into(),
            JobSpec::Als(
                NmfOptions::new(5)
                    .with_iters(50)
                    .with_seed(42)
                    .with_sparsity(SparsityMode::both(200, 2000.min(tdm.n_docs() * 5)))
                    .with_track_error(false),
            ),
        ),
        (
            "column-wise 40/topic".into(),
            JobSpec::Als(
                NmfOptions::new(5)
                    .with_iters(50)
                    .with_seed(42)
                    .with_sparsity(SparsityMode::PerColumn {
                        t_u_col: Some(40),
                        t_v_col: Some(400.min(tdm.n_docs())),
                    })
                    .with_track_error(false),
            ),
        ),
        (
            "sequential (Alg.3)".into(),
            JobSpec::Sequential(
                SequentialOptions::new(5, 10)
                    .with_budgets(40, 400.min(tdm.n_docs()))
                    .with_seed(42),
            ),
        ),
    ];
    let ids: Vec<_> = configs
        .iter()
        .map(|(_, spec)| mgr.submit(Arc::clone(&tdm), spec.clone()))
        .collect();
    println!("\n[factorize] {} concurrent jobs on 4 workers:", ids.len());
    println!("config | iters | time | error | acc | nnz(U) | nnz(V) | peak nnz");
    let mut best: Option<(f64, Arc<esnmf::nmf::NmfResult>)> = None;
    for ((name, _), id) in configs.iter().zip(&ids) {
        let r = mgr.wait_result(*id)?;
        let acc = mean_topic_accuracy(&r.v, &labels, n_journals);
        let err = esnmf::nmf::rel_error_sparse(&tdm.a, &r.u, &r.v, tdm.a.fro_norm_sq());
        println!(
            "{name} | {} | {:.2}s | {err:.4} | {acc:.4} | {} | {} | {}",
            r.iterations,
            r.elapsed_s,
            r.u.nnz(),
            r.v.nnz(),
            r.memory.max_combined_nnz
        );
        metrics.counter("jobs.completed").inc();
        if best.as_ref().map(|(a, _)| acc > *a).unwrap_or(true) {
            best = Some((acc, r));
        }
    }
    println!("[factorize] wall-clock for all jobs: {:.2}s", fact_timer.elapsed_s());

    // ---- 4. XLA artifact backend cross-check ---------------------------
    if runtime::artifacts_available() {
        let dir = runtime::artifact_dir();
        let manifest = esnmf::runtime::Manifest::load(&dir)?;
        // fit a subcorpus to the largest compiled artifact
        if let Some(prog) = manifest.best_fit(ProgramKind::AlsIter, 1, 1, 8) {
            let sub_spec = corpus::CorpusSpec {
                n_docs: (prog.m / 2).min(1200),
                doc_len_mean: 60,
                topic_tail: 60,
                background_tail: 40,
                ..corpus::pubmed_sim(Scale::Tiny)
            };
            let sub = corpus::generate_tdm(&sub_spec, 7);
            if sub.n_terms() <= prog.n && sub.n_docs() <= prog.m {
                let guard = XlaExecutor::spawn(dir)?;
                let opts = NmfOptions::new(prog.k)
                    .with_iters(10)
                    .with_seed(7)
                    .with_sparsity(SparsityMode::both(300, 900));
                let xr = XlaBackend::new(guard.handle.clone(), prog.n, prog.m, prog.k)
                    .factorize(&sub, &opts)?;
                let nr = esnmf::nmf::factorize(&sub, &opts);
                println!(
                    "\n[xla] artifact {} on {} terms × {} docs: error xla {:.4} vs native {:.4} (Δ {:.1e}), {:.0} ms/iter",
                    prog.name,
                    sub.n_terms(),
                    sub.n_docs(),
                    xr.final_error(),
                    nr.final_error(),
                    (xr.final_error() - nr.final_error()).abs(),
                    xr.elapsed_s * 1000.0 / xr.iterations as f64
                );
            } else {
                println!("\n[xla] skipped: subcorpus larger than artifact shape");
            }
        }
    } else {
        println!("\n[xla] artifacts not built — skipping cross-check (run `make artifacts`)");
    }

    // ---- 5. serve and query --------------------------------------------
    let (best_acc, best_result) = best.expect("at least one job");
    let model = Arc::new(TopicModel::new(
        best_result.u.clone(),
        best_result.v.clone(),
        tdm.terms.clone(),
    ));
    println!("\n[serve] best model (accuracy {best_acc:.4}) topics:");
    print!("{}", format_topic_table(&topic_term_table(&model.u, &tdm.terms, 5), model.k()));
    let server = TopicServer::start("127.0.0.1:0", Arc::clone(&model), metrics.clone())?;
    let addr = server.addr();

    let query_timer = Timer::start();
    let mut latencies_ms = Vec::new();
    let n_queries = 500;
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let queries = [
        "CLASSIFY stroke seizure brain imaging",
        "CLASSIFY students curriculum teaching",
        "CLASSIFY allele genotype marker",
        "TOPTERMS 0 5",
        "TOPICS",
    ];
    for i in 0..n_queries {
        let q = queries[i % queries.len()];
        let t = Timer::start();
        writeln!(writer, "{q}")?;
        let mut line = String::new();
        reader.read_line(&mut line)?;
        anyhow::ensure!(line.starts_with("OK"), "query failed: {line}");
        latencies_ms.push(t.elapsed_s() * 1e3);
    }
    writeln!(writer, "QUIT")?;
    let qps = n_queries as f64 / query_timer.elapsed_s();
    println!(
        "\n[serve] {n_queries} queries: {qps:.0} qps, latency p50 {:.3} ms p99 {:.3} ms",
        stats::median(&latencies_ms),
        stats::quantile(&latencies_ms, 0.99)
    );
    println!("[metrics] {}", metrics.format());
    server.stop();
    println!("\n[e2e] total wall-clock {:.2}s — all layers composed ✓", total.elapsed_s());
    Ok(())
}
