//! Even-topic demo (Table 1 / Fig. 7): global enforcement skews nonzeros
//! across topics; column-wise enforcement and sequential ALS fix it.
//!
//! ```bash
//! cargo run --release --example wikipedia_topics -- [scale]
//! ```

use esnmf::corpus::{generate_tdm, wikipedia_sim, Scale};
use esnmf::eval::topics::{column_nnz_cv, format_topic_table, topic_term_table};
use esnmf::nmf::{
    factorize, factorize_sequential, NmfOptions, SequentialOptions, SparsityMode,
};

fn main() {
    let scale = std::env::args()
        .nth(1)
        .and_then(|s| Scale::parse(&s))
        .unwrap_or(Scale::Tiny);
    let tdm = generate_tdm(&wikipedia_sim(scale), 42);
    println!(
        "wikipedia-sim at {scale:?}: {} terms × {} docs",
        tdm.n_terms(),
        tdm.n_docs()
    );

    // Table 1: 50 nonzeros globally → uneven topics
    let global = factorize(
        &tdm,
        &NmfOptions::new(5)
            .with_iters(50)
            .with_seed(42)
            .with_sparsity(SparsityMode::u_only(50)),
    );
    println!(
        "\n== global top-50 U (uneven; per-topic nnz {:?}, cv {:.2}) ==",
        global.u.col_nnz(),
        column_nnz_cv(&global.u)
    );
    print!("{}", format_topic_table(&topic_term_table(&global.u, &tdm.terms, 5), 5));

    // Fix 1: column-wise (10 per topic)
    let colwise = factorize(
        &tdm,
        &NmfOptions::new(5)
            .with_iters(50)
            .with_seed(42)
            .with_sparsity(SparsityMode::PerColumn {
                t_u_col: Some(10),
                t_v_col: None,
            }),
    );
    println!(
        "\n== column-wise 10/topic (even; per-topic nnz {:?}) ==",
        colwise.u.col_nnz()
    );
    print!("{}", format_topic_table(&topic_term_table(&colwise.u, &tdm.terms, 5), 5));

    // Fix 2: sequential ALS (10 per topic, one topic at a time)
    let seq = factorize_sequential(
        &tdm,
        &SequentialOptions::new(5, 20)
            .with_budgets(10, tdm.n_docs())
            .with_seed(42),
    );
    println!(
        "\n== sequential ALS 10/topic (even; per-topic nnz {:?}, {:.3}s) ==",
        seq.u.col_nnz(),
        seq.elapsed_s
    );
    print!("{}", format_topic_table(&topic_term_table(&seq.u, &tdm.terms, 5), 5));
}
