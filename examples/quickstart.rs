//! Quickstart: build a small corpus, factorize it with enforced-sparsity
//! ALS, and print the topics.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use esnmf::corpus::{generate_tdm, reuters_sim, Scale};
use esnmf::eval::topics::{format_topic_table, topic_term_table};
use esnmf::nmf::{factorize, NmfOptions, SparsityMode};

fn main() {
    // 1. A corpus: ~100 synthetic newswire documents (swap in
    //    `corpus::loader::load_dir` for your own directory of .txt files).
    let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 42);
    println!(
        "corpus: {} terms × {} docs, {:.2}% sparse",
        tdm.n_terms(),
        tdm.n_docs(),
        tdm.a.sparsity() * 100.0
    );

    // 2. Factorize: 5 topics, keep U to 55 nonzeros (Algorithm 2).
    let opts = NmfOptions::new(5)
        .with_iters(50)
        .with_seed(42)
        .with_sparsity(SparsityMode::u_only(55));
    let result = factorize(&tdm, &opts);

    // 3. Inspect.
    println!(
        "finished in {:.3}s; residual {:.2e}, error {:.4}, nnz(U) = {}",
        result.elapsed_s,
        result.final_residual(),
        result.final_error(),
        result.u.nnz()
    );
    println!("\nTop terms per topic:");
    print!(
        "{}",
        format_topic_table(&topic_term_table(&result.u, &tdm.terms, 5), 5)
    );
}
