//! Clustering-accuracy demo (Figures 4/5): factorize the simulated
//! five-journal PubMed corpus at several sparsity levels and report the
//! Eq. 3.3 accuracy for each.
//!
//! ```bash
//! cargo run --release --example pubmed_clustering -- [scale]
//! ```

use esnmf::corpus::{generate_tdm, pubmed_sim, Scale};
use esnmf::eval::mean_topic_accuracy;
use esnmf::nmf::{factorize, NmfOptions, SparsityMode};

fn main() {
    let scale = std::env::args()
        .nth(1)
        .and_then(|s| Scale::parse(&s))
        .unwrap_or(Scale::Tiny);
    let tdm = generate_tdm(&pubmed_sim(scale), 42);
    let labels = tdm.doc_labels.clone().expect("pubmed-sim is labeled");
    let n_journals = tdm.label_names.len();
    println!(
        "pubmed-sim at {scale:?}: {} terms × {} docs, journals: {:?}",
        tdm.n_terms(),
        tdm.n_docs(),
        tdm.label_names
    );

    println!("\nnnz(V budget) | accuracy | nnz(V) actual | error");
    for t in [20usize, 60, 200, 600, 2000] {
        let t = t.min(tdm.n_docs() * 5);
        let r = factorize(
            &tdm,
            &NmfOptions::new(5)
                .with_iters(50)
                .with_seed(42)
                .with_sparsity(SparsityMode::v_only(t)),
        );
        let acc = mean_topic_accuracy(&r.v, &labels, n_journals);
        println!(
            "{t:>13} | {acc:>8.4} | {:>13} | {:.4}",
            r.v.nnz(),
            r.final_error()
        );
    }

    let dense = factorize(&tdm, &NmfOptions::new(5).with_iters(50).with_seed(42));
    let dense_acc = mean_topic_accuracy(&dense.v, &labels, n_journals);
    println!(
        "{:>13} | {dense_acc:>8.4} | {:>13} | {:.4}   (dense baseline)",
        "dense",
        dense.v.nnz(),
        dense.final_error()
    );
}
