//! XLA/PJRT backend demo: run the same enforced-sparsity ALS through the
//! AOT-compiled JAX/Pallas artifact and cross-check against the native
//! sparse engine.
//!
//! Requires `make artifacts` to have produced `artifacts/manifest.json`.
//!
//! ```bash
//! make artifacts && cargo run --release --example xla_offload
//! ```

use esnmf::backend::{AlsBackend, NativeBackend, XlaBackend};
use esnmf::corpus::{generate_tdm, CorpusSpec, TopicSpec};
use esnmf::corpus::words;
use esnmf::nmf::{NmfOptions, SparsityMode};
use esnmf::runtime::{self, ProgramKind, XlaExecutor};

fn main() -> anyhow::Result<()> {
    if !runtime::artifacts_available() {
        eprintln!("artifacts/manifest.json not found — run `make artifacts` first");
        std::process::exit(2);
    }
    let dir = runtime::artifact_dir();
    let manifest = esnmf::runtime::Manifest::load(&dir)?;

    // a corpus sized to fit the (256 × 512, k=5) compiled artifact
    let spec = CorpusSpec {
        name: "xla-demo".into(),
        topics: vec![
            TopicSpec { name: "coffee".into(), seeds: words::COFFEE.to_vec() },
            TopicSpec { name: "science".into(), seeds: words::SCIENCE.to_vec() },
            TopicSpec { name: "music".into(), seeds: words::MUSIC.to_vec() },
            TopicSpec { name: "sport".into(), seeds: words::SPORT.to_vec() },
            TopicSpec { name: "religion".into(), seeds: words::RELIGION.to_vec() },
        ],
        n_docs: 400,
        doc_len_mean: 40,
        topic_tail: 8,
        background_tail: 6,
        background_frac: 0.25,
        mixture: 0.1,
        zipf_s: 1.05,
    };
    let tdm = generate_tdm(&spec, 7);
    let k = 5;
    let prog = manifest
        .best_fit(ProgramKind::AlsIter, tdm.n_terms(), tdm.n_docs(), k)
        .ok_or_else(|| anyhow::anyhow!(
            "no artifact fits {} terms × {} docs k={k}",
            tdm.n_terms(), tdm.n_docs()
        ))?;
    println!(
        "corpus {} terms × {} docs → artifact {} ({}, {}, {})",
        tdm.n_terms(), tdm.n_docs(), prog.name, prog.n, prog.m, prog.k
    );

    let guard = XlaExecutor::spawn(dir)?;
    println!("PJRT platform: {}", guard.handle.platform()?);

    let opts = NmfOptions::new(k)
        .with_iters(15)
        .with_seed(11)
        .with_sparsity(SparsityMode::both(60, 120));

    let xla_result = XlaBackend::new(guard.handle.clone(), prog.n, prog.m, prog.k)
        .factorize(&tdm, &opts)?;
    let native_result = NativeBackend::new().factorize(&tdm, &opts)?;

    println!("\nbackend | iters | time | final error | nnz(U) | nnz(V)");
    for (name, r) in [("xla", &xla_result), ("native", &native_result)] {
        println!(
            "{name:>7} | {:>5} | {:>6.3}s | {:.5} | {:>6} | {:>6}",
            r.iterations,
            r.elapsed_s,
            r.final_error(),
            r.u.nnz(),
            r.v.nnz()
        );
    }
    let diff = (xla_result.final_error() - native_result.final_error()).abs();
    println!("\n|error(xla) − error(native)| = {diff:.2e}");
    anyhow::ensure!(diff < 1e-2, "backends diverged");
    println!("backends agree ✓  (python was never on this path)");
    Ok(())
}
