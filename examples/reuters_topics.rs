//! Figure-2 style demo: compare enforced-sparsity ALS against dense
//! projected ALS on the newswire corpus — convergence curves and topics.
//!
//! ```bash
//! cargo run --release --example reuters_topics -- [scale] [t_u]
//! ```

use esnmf::corpus::{generate_tdm, reuters_sim, Scale};
use esnmf::eval::topics::{format_topic_table, topic_term_table};
use esnmf::nmf::{factorize, NmfOptions, SparsityMode};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let scale = args
        .first()
        .and_then(|s| Scale::parse(s))
        .unwrap_or(Scale::Tiny);
    let t_u: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(55);

    let tdm = generate_tdm(&reuters_sim(scale), 42);
    println!(
        "reuters-sim at {scale:?}: {} terms × {} docs",
        tdm.n_terms(),
        tdm.n_docs()
    );

    let iters = 75;
    let sparse = factorize(
        &tdm,
        &NmfOptions::new(5)
            .with_iters(iters)
            .with_seed(42)
            .with_sparsity(SparsityMode::u_only(t_u)),
    );
    let dense = factorize(&tdm, &NmfOptions::new(5).with_iters(iters).with_seed(42));

    println!("\niter | residual(sparse) | error(sparse) | residual(dense) | error(dense)");
    for i in (0..iters).step_by(5) {
        println!(
            "{:>4} | {:.3e} | {:.4} | {:.3e} | {:.4}",
            i + 1,
            sparse.residuals[i],
            sparse.errors[i],
            dense.residuals[i],
            dense.errors[i]
        );
    }
    println!(
        "\nfinal: sparse error {:.4} (nnz {}), dense error {:.4} (nnz {})",
        sparse.final_error(),
        sparse.u.nnz(),
        dense.final_error(),
        dense.u.nnz()
    );

    println!("\nSparsity-enforced U ({t_u} nonzeros):");
    print!("{}", format_topic_table(&topic_term_table(&sparse.u, &tdm.terms, 5), 5));
    println!("\nFully dense U:");
    print!("{}", format_topic_table(&topic_term_table(&dense.u, &tdm.terms, 5), 5));
}
